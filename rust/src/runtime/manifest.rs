//! `artifacts/manifest.json` schema: the contract `python/compile/aot.py`
//! writes and the Rust runtime consumes (argument order, shapes,
//! deterministic generator specs, golden output fingerprints).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::detgen;
use crate::util::json::{self, Value};

/// Generator spec for one argument.
#[derive(Debug, Clone)]
pub enum GenSpec {
    /// Deterministic f32 tensor (see `detgen`).
    Det { seed: u32, scale: f64, offset: f64 },
    /// A fixed i32 scalar (e.g. `kv_len`).
    I32 { value: i32 },
}

/// One argument of an artifact's entry computation.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    pub gen: GenSpec,
}

impl ArgSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Materialize the argument exactly as the Python golden run did.
    pub fn generate_f32(&self) -> Option<Vec<f32>> {
        match &self.gen {
            GenSpec::Det { seed, scale, offset } => Some(detgen::det_f32(
                self.element_count(),
                *seed,
                *scale as f32,
                *offset as f32,
            )),
            GenSpec::I32 { .. } => None,
        }
    }

    fn from_json(v: &Value) -> Result<ArgSpec> {
        let gen_v = v.req("gen")?;
        let gen = match gen_v.req("kind")?.as_str() {
            Some("det") => GenSpec::Det {
                seed: gen_v.req("seed")?.as_u64().context("seed")? as u32,
                scale: gen_v.req("scale")?.as_f64().context("scale")?,
                offset: gen_v.req("offset")?.as_f64().context("offset")?,
            },
            Some("i32") => GenSpec::I32 {
                value: gen_v.req("value")?.as_i64().context("value")? as i32,
            },
            other => anyhow::bail!("unknown generator kind {other:?}"),
        };
        Ok(ArgSpec {
            name: v.req("name")?.as_str().context("name")?.to_string(),
            shape: v
                .req("shape")?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_u64().context("dim").map(|d| d as usize))
                .collect::<Result<_>>()?,
            dtype: v.req("dtype")?.as_str().context("dtype")?.to_string(),
            gen,
        })
    }
}

/// Golden fingerprint of one output.
#[derive(Debug, Clone)]
pub struct OutputFingerprint {
    pub shape: Vec<usize>,
    pub l2: f64,
    pub first: Vec<f64>,
}

impl OutputFingerprint {
    fn from_json(v: &Value) -> Result<OutputFingerprint> {
        Ok(OutputFingerprint {
            shape: v
                .req("shape")?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_u64().context("dim").map(|d| d as usize))
                .collect::<Result<_>>()?,
            l2: v.req("l2")?.as_f64().context("l2")?,
            first: v
                .req("first")?
                .as_arr()
                .context("first")?
                .iter()
                .map(|d| d.as_f64().context("first elem"))
                .collect::<Result<_>>()?,
        })
    }
}

/// One AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<OutputFingerprint>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactEntry>,
    pub root: PathBuf,
}

impl Manifest {
    /// Parse from JSON text (root path supplied separately).
    pub fn parse(text: &str, root: PathBuf) -> Result<Manifest> {
        let v = json::parse(text).context("parsing manifest.json")?;
        let artifacts = v
            .req("artifacts")?
            .as_arr()
            .context("artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactEntry {
                    name: a.req("name")?.as_str().context("name")?.to_string(),
                    file: a.req("file")?.as_str().context("file")?.to_string(),
                    args: a
                        .req("args")?
                        .as_arr()
                        .context("args")?
                        .iter()
                        .map(ArgSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: a
                        .req("outputs")?
                        .as_arr()
                        .context("outputs")?
                        .iter()
                        .map(OutputFingerprint::from_json)
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<_>>()?;
        Ok(Manifest { artifacts, root })
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir.to_path_buf())
    }

    /// Default artifacts directory: `$SNITCH_FM_ARTIFACTS` or `artifacts/`
    /// under the workspace root (resolves from any working directory).
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("SNITCH_FM_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not in manifest"))
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.root.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "seed_stride": 1,
      "artifacts": [{
        "name": "t", "file": "t.hlo.txt",
        "args": [
          {"name": "x", "shape": [2, 3], "dtype": "f32",
           "gen": {"kind": "det", "seed": 5, "scale": 1.0, "offset": 0.0}},
          {"name": "n", "shape": [], "dtype": "i32",
           "gen": {"kind": "i32", "value": 17}}
        ],
        "outputs": [{"shape": [2, 3], "l2": 1.5, "first": [0.1]}]
      }]
    }"#;

    #[test]
    fn parse_manifest_json() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let a = m.get("t").unwrap();
        assert_eq!(a.args[0].element_count(), 6);
        let v = a.args[0].generate_f32().unwrap();
        assert_eq!(v, crate::runtime::detgen::det_f32(6, 5, 1.0, 0.0));
        assert!(a.args[1].generate_f32().is_none());
        match a.args[1].gen {
            GenSpec::I32 { value } => assert_eq!(value, 17),
            _ => panic!("wrong kind"),
        }
        assert_eq!(a.outputs[0].l2, 1.5);
        assert!(m.get("missing").is_err());
        assert_eq!(m.hlo_path(a), PathBuf::from("/tmp/t.hlo.txt"));
    }

    #[test]
    fn scalar_shape_has_one_element() {
        let a = ArgSpec {
            name: "s".into(),
            shape: vec![],
            dtype: "f32".into(),
            gen: GenSpec::Det { seed: 0, scale: 1.0, offset: 0.0 },
        };
        assert_eq!(a.element_count(), 1);
    }
}
