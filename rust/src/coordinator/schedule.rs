//! Layer -> platform scheduling and pricing (paper Sec. V).
//!
//! Maps each [`Layer`] of a block onto the kernel timing models, honoring
//! the paper's fusion decisions: the out-projection uses the fused
//! concat+linear (tree reduction), GELU is fused with mlp-up, and fused
//! inputs skip their HBM read.
//!
//! Every path is batch-aware: a layer's `b` requests stack along the token
//! rows, so one weight stream from HBM feeds `b*m` rows of work. Batched
//! AR decode therefore turns the pure GEMV (the <10% utilization mode of
//! Table III) into a skinny GEMM whose arithmetic intensity — and FPU
//! utilization — grows with the batch.

use std::collections::HashMap;

use crate::arch::{FpFormat, MemLevel, PlatformConfig};
use crate::kernels;
use crate::kernels::gemm::OperandHome;
use crate::model::{block_layers_batched, Layer, LayerKind, Mode, ModelConfig};
use crate::sim::KernelCost;

/// Row count below which a *batched* GEMM keeps the N-split
/// weight-streaming schedule (each cluster owns output columns, weights
/// read from HBM exactly once). Above it, the M-split blocked schedule
/// wins: its per-cluster weight broadcast costs ~C x the HBM reads, but
/// with >= 16 rows per cluster the inner loops are compute-bound enough
/// to hide them (the crossover sits near rows ~= 16 * clusters on the
/// default platform; switching earlier would jump the cost discontinuity
/// into the bench's b = 1..32 sweep).
fn skinny_rows_threshold(platform: &PlatformConfig) -> u64 {
    platform.total_clusters() as u64 * 16
}

/// Cost of one layer on the platform. This is the single dispatch path —
/// the exact head geometry (`heads`, `p`) travels on the [`Layer`], so no
/// caller-side special cases (and no divisor guessing) remain.
pub fn layer_cost(layer: &Layer, fmt: FpFormat, platform: &PlatformConfig) -> KernelCost {
    let rows = layer.batch_rows();
    match layer.kind {
        LayerKind::Gemm => {
            let home = OperandHome {
                a: if layer.fused_input { MemLevel::Spm } else { MemLevel::Hbm },
                b: MemLevel::Hbm,
                c: MemLevel::Hbm,
            };
            if layer.b > 1 && rows < skinny_rows_threshold(platform) {
                // Batched decode: m = b token rows against one weight
                // stream (N-split). The `b > 1` guard is deliberate: at
                // b = 1 the layer must price exactly like the legacy
                // single-request path (an acceptance invariant), which
                // routes through `gemm_cost` — itself dispatching to this
                // same gemv schedule below `total_clusters` rows. A
                // small-s single-request NAR pass therefore keeps its
                // historical M-split price even where a batched layer of
                // equal row count would stream N-split.
                kernels::gemv_cost(rows, layer.k, layer.n, fmt, platform, home)
            } else {
                kernels::gemm_cost(rows, layer.k, layer.n, fmt, platform, home)
            }
        }
        LayerKind::FlashAttention => kernels::flash_attention_cost(
            // Each request attends to its own KV history: b*H independent
            // head instances spread across the clusters.
            layer.batch_heads(),
            layer.n, // sq
            layer.skv,
            layer.p,
            fmt,
            layer.causal,
            platform,
        ),
        LayerKind::FusedConcatLinear => {
            if platform.features.cluster_to_cluster {
                kernels::fused_concat_linear_cost(
                    rows, layer.heads, layer.p, layer.n, fmt, platform,
                )
            } else {
                kernels::unfused_concat_linear_cost(
                    rows, layer.heads, layer.p, layer.n, fmt, platform,
                )
            }
        }
        LayerKind::Layernorm => kernels::layernorm_cost(rows, layer.k, fmt, platform),
        LayerKind::Gelu => {
            kernels::gelu_cost(rows, layer.k, fmt, layer.fused_input, platform)
        }
    }
}

/// Per-block and per-model cost summary.
#[derive(Debug, Clone, Default)]
pub struct ModelCost {
    /// Total cycles for one forward pass (NAR) or one token step (AR).
    pub cycles: u64,
    /// Aggregate kernel costs by class.
    pub by_kind: HashMap<LayerKind, KernelCost>,
    /// Aggregate kernel costs by layer label ("q-proj", "mlp-up", ...).
    pub by_label: HashMap<&'static str, KernelCost>,
    /// Total cost.
    pub total: KernelCost,
    /// Blocks priced.
    pub blocks: u64,
    /// Concurrent requests priced together (1 = the legacy single-request
    /// path).
    pub batch: u64,
}

impl ModelCost {
    /// Fraction of cycles spent in `kind`.
    pub fn fraction(&self, kind: LayerKind) -> f64 {
        if self.total.cycles == 0 {
            return 0.0;
        }
        self.by_kind.get(&kind).map(|c| c.cycles as f64).unwrap_or(0.0)
            / self.total.cycles as f64
    }
}

/// Cost of one transformer block for a single request.
pub fn block_cost(
    cfg: &ModelConfig,
    mode: Mode,
    s: u64,
    kv_len: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    block_cost_batched(cfg, mode, 1, s, kv_len, fmt, platform)
}

/// Cost of one transformer block for `b` concurrent requests.
pub fn block_cost_batched(
    cfg: &ModelConfig,
    mode: Mode,
    b: u64,
    s: u64,
    kv_len: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    let mut out = ModelCost { blocks: 1, batch: b.max(1), ..Default::default() };
    for layer in block_layers_batched(cfg, mode, b.max(1), s, kv_len) {
        let c = layer_cost(&layer, fmt, platform);
        let slot = out.by_kind.entry(layer.kind).or_default();
        *slot = slot.then(c);
        let slot = out.by_label.entry(layer.label).or_default();
        *slot = slot.then(c);
        out.total = out.total.then(c);
    }
    out.cycles = out.total.cycles;
    out
}

/// Cost of a full single-request model pass: `blocks` x block cost. In AR
/// mode, `s` is the current KV length (per-token cost at that point in
/// the sequence).
pub fn model_cost(
    cfg: &ModelConfig,
    mode: Mode,
    s: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    model_cost_batched(cfg, mode, 1, s, fmt, platform)
}

/// Cost of a full model pass over `b` concurrent requests. In AR mode the
/// batch advances one token per request per pass (`b` tokens total
/// against KV length `s`).
pub fn model_cost_batched(
    cfg: &ModelConfig,
    mode: Mode,
    b: u64,
    s: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    let (bs, kv) = match mode {
        Mode::Nar => (s, 0),
        Mode::Ar => (1, s),
    };
    let one = block_cost_batched(cfg, mode, b, bs, kv, fmt, platform);
    let mut out = ModelCost { blocks: cfg.blocks, batch: b.max(1), ..Default::default() };
    for (k, v) in &one.by_kind {
        out.by_kind.insert(*k, v.repeat(cfg.blocks));
    }
    for (k, v) in &one.by_label {
        out.by_label.insert(*k, v.repeat(cfg.blocks));
    }
    out.total = one.total.repeat(cfg.blocks);
    out.cycles = out.total.cycles;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn gemm_dominates_nar_latency() {
        // Fig. 10: GEMMs are ~66% of GPT-J FP32 NAR latency.
        let cfg = ModelConfig::gpt_j();
        let mc = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp32, &occ());
        let gemm_frac = mc.fraction(LayerKind::Gemm)
            + mc.fraction(LayerKind::FusedConcatLinear);
        assert!(gemm_frac > 0.5, "gemm fraction {gemm_frac}");
        let act_frac = mc.fraction(LayerKind::Layernorm) + mc.fraction(LayerKind::Gelu);
        assert!(act_frac < 0.2, "activations {act_frac}");
    }

    #[test]
    fn ar_gemm_fraction_higher_than_nar() {
        // Fig. 10: AR is even more GEMM-dominated (97% FP32) — the plain
        // GEMV weight streaming eats the token latency.
        let cfg = ModelConfig::gpt_j();
        let nar = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp32, &occ());
        let ar = model_cost(&cfg, Mode::Ar, 1024, FpFormat::Fp32, &occ());
        let f = |mc: &ModelCost| mc.fraction(LayerKind::Gemm);
        assert!(f(&ar) > f(&nar), "ar {} vs nar {}", f(&ar), f(&nar));
        assert!(f(&ar) > 0.85, "ar gemv share {}", f(&ar));
    }

    #[test]
    fn fa_fraction_grows_at_fp8() {
        // Fig. 10: FA-2's relative share grows FP32 -> FP8 (FP32 softmax).
        let cfg = ModelConfig::gpt_j();
        let f32c = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp32, &occ());
        let f8c = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp8, &occ());
        assert!(
            f8c.fraction(LayerKind::FlashAttention)
                > f32c.fraction(LayerKind::FlashAttention),
            "fp8 {} vs fp32 {}",
            f8c.fraction(LayerKind::FlashAttention),
            f32c.fraction(LayerKind::FlashAttention)
        );
    }

    #[test]
    fn model_cost_scales_with_blocks() {
        let mut cfg = ModelConfig::vit_b();
        let one = model_cost(&cfg, Mode::Nar, 197, FpFormat::Fp32, &occ());
        cfg.blocks *= 2;
        let two = model_cost(&cfg, Mode::Nar, 197, FpFormat::Fp32, &occ());
        assert_eq!(two.cycles, 2 * one.cycles);
    }

    #[test]
    fn block_cost_covers_all_kinds() {
        let cfg = ModelConfig::vit_b();
        let bc = block_cost(&cfg, Mode::Nar, 197, 0, FpFormat::Fp32, &occ());
        for kind in [
            LayerKind::Gemm,
            LayerKind::FlashAttention,
            LayerKind::FusedConcatLinear,
            LayerKind::Layernorm,
            LayerKind::Gelu,
        ] {
            assert!(bc.by_kind.contains_key(&kind), "{kind:?} missing");
        }
        let sum: u64 = bc.by_kind.values().map(|c| c.cycles).sum();
        assert_eq!(sum, bc.cycles);
    }

    #[test]
    fn batched_block_flops_scale_linearly() {
        // Useful work is proportional to the batch; NAR attention work too
        // (each request attends within its own sequence).
        let cfg = ModelConfig::gpt_j();
        for mode in [Mode::Nar, Mode::Ar] {
            let (s, kv) = match mode {
                Mode::Nar => (256, 0),
                Mode::Ar => (1, 512),
            };
            let one = block_cost_batched(&cfg, mode, 1, s, kv, FpFormat::Fp32, &occ());
            let four = block_cost_batched(&cfg, mode, 4, s, kv, FpFormat::Fp32, &occ());
            assert_eq!(four.total.flops, 4 * one.total.flops, "{mode:?}");
        }
    }

    #[test]
    fn batched_ar_cheaper_than_serial_decode() {
        // The whole point: one batched step prices far below b serial
        // steps because the weight stream is shared.
        let cfg = ModelConfig::gpt_j();
        let one = model_cost(&cfg, Mode::Ar, 1024, FpFormat::Fp32, &occ());
        let b = 8;
        let batched = model_cost_batched(&cfg, Mode::Ar, b, 1024, FpFormat::Fp32, &occ());
        assert!(
            batched.cycles < b * one.cycles / 2,
            "batched {} vs {}x serial {}",
            batched.cycles,
            b,
            b * one.cycles
        );
    }

    #[test]
    fn batched_ar_utilization_rises_with_b() {
        let cfg = ModelConfig::gpt_j();
        let p = occ();
        let mut prev = 0.0;
        for b in [1u64, 2, 4, 8, 16, 32] {
            let mc = model_cost_batched(&cfg, Mode::Ar, b, 1024, FpFormat::Fp32, &p);
            let util = metrics::fpu_utilization(&mc.total, FpFormat::Fp32, &p);
            assert!(util > prev, "b={b}: util {util} !> {prev}");
            prev = util;
        }
    }
}
