//! Minimal TOML-subset parser for run configs.
//!
//! Supports exactly what the checked-in configs use: `[section]` headers,
//! `key = value` with string/integer/float/boolean values, `#` comments
//! and blank lines. Nested tables/arrays are out of scope on purpose.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum Scalar {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Scalar {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Scalar::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Scalar::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Scalar::Float(f) => Some(*f),
            Scalar::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Scalar::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section -> key -> value`. Keys before any `[section]` land in `""`.
pub type Doc = BTreeMap<String, BTreeMap<String, Scalar>>;

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let Some(name) = name.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`", lineno + 1);
        };
        let key = key.trim().to_string();
        let value = parse_scalar(value.trim())
            .ok_or_else(|| anyhow::anyhow!("line {}: bad value {value:?}", lineno + 1))?;
        doc.entry(section.clone()).or_default().insert(key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is respected.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_scalar(s: &str) -> Option<Scalar> {
    if let Some(stripped) = s.strip_prefix('"') {
        return stripped.strip_suffix('"').map(|inner| Scalar::Str(inner.to_string()));
    }
    match s {
        "true" => return Some(Scalar::Bool(true)),
        "false" => return Some(Scalar::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(Scalar::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Some(Scalar::Float(f));
    }
    None
}

/// Convenience typed getters over a parsed doc.
pub fn get_str<'d>(doc: &'d Doc, section: &str, key: &str) -> Option<&'d str> {
    doc.get(section)?.get(key)?.as_str()
}

pub fn get_u64(doc: &Doc, section: &str, key: &str) -> Option<u64> {
    doc.get(section)?.get(key)?.as_u64()
}

pub fn get_f64(doc: &Doc, section: &str, key: &str) -> Option<f64> {
    doc.get(section)?.get(key)?.as_f64()
}

pub fn get_bool(doc: &Doc, section: &str, key: &str) -> Option<bool> {
    doc.get(section)?.get(key)?.as_bool()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run config
[platform]
clusters = 8
xssr = false
freq_ghz = 1.5

[model]
preset = "gpt-j"   # with a comment

[run]
mode = "ar"
seq = 2048
"#;

    #[test]
    fn parse_sample() {
        let d = parse(SAMPLE).unwrap();
        assert_eq!(get_u64(&d, "platform", "clusters"), Some(8));
        assert_eq!(get_bool(&d, "platform", "xssr"), Some(false));
        assert_eq!(get_f64(&d, "platform", "freq_ghz"), Some(1.5));
        assert_eq!(get_str(&d, "model", "preset"), Some("gpt-j"));
        assert_eq!(get_str(&d, "run", "mode"), Some("ar"));
        assert_eq!(get_u64(&d, "run", "seq"), Some(2048));
        assert_eq!(get_u64(&d, "run", "missing"), None);
        assert_eq!(get_u64(&d, "nope", "seq"), None);
    }

    #[test]
    fn hash_in_string_kept() {
        let d = parse("[a]\nx = \"val#ue\"\n").unwrap();
        assert_eq!(get_str(&d, "a", "x"), Some("val#ue"));
    }

    #[test]
    fn int_vs_float() {
        let d = parse("[a]\ni = 3\nf = 3.5\n").unwrap();
        assert_eq!(d["a"]["i"], Scalar::Int(3));
        assert_eq!(d["a"]["f"], Scalar::Float(3.5));
        assert_eq!(d["a"]["i"].as_f64(), Some(3.0));
    }

    #[test]
    fn errors() {
        assert!(parse("[a\nx=1").is_err());
        assert!(parse("[a]\njust a line").is_err());
        assert!(parse("[a]\nx = @bad").is_err());
    }
}
