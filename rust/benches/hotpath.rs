//! Hot-path microbenches (§Perf): the simulator and coordinator routines
//! that every experiment sweep drives, plus the PJRT execute path when
//! artifacts are present. Used for the before/after log in
//! EXPERIMENTS.md §Perf.

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::schedule::{block_cost, model_cost};
use snitch_fm::kernels::gemm::OperandHome;
use snitch_fm::kernels::{flash_attention_cost, gemm_cost};
use snitch_fm::model::{Mode, ModelConfig};
use snitch_fm::runtime::Runtime;
use snitch_fm::tiling::plan_gemm;

fn main() {
    common::header("hotpath", "simulator/coordinator/runtime microbenches");
    let p = PlatformConfig::occamy();

    let (t, _) = common::time_median(50, || plan_gemm(2048, 16384, 4096, FpFormat::Fp8, &p));
    common::report_timing("tiling::plan_gemm", t);

    let (t, _) = common::time_median(50, || {
        gemm_cost(1024, 4096, 16384, FpFormat::Fp32, &p, OperandHome::default())
    });
    common::report_timing("kernels::gemm_cost(gpt-j mlp)", t);

    let (t, _) = common::time_median(50, || {
        flash_attention_cost(16, 1024, 1024, 256, FpFormat::Fp32, true, &p)
    });
    common::report_timing("kernels::flash_attention_cost", t);

    let cfg = ModelConfig::gpt_j();
    let (t, _) =
        common::time_median(20, || block_cost(&cfg, Mode::Nar, 1024, 0, FpFormat::Fp32, &p));
    common::report_timing("coordinator::block_cost(gpt-j nar)", t);

    let (t, _) = common::time_median(10, || model_cost(&cfg, Mode::Nar, 2048, FpFormat::Fp8, &p));
    common::report_timing("coordinator::model_cost(gpt-j s2048)", t);

    // Full Fig. 7-style sweep: the workload every bench drives.
    let (t, _) = common::time_median(5, || {
        let e = snitch_fm::coordinator::InferenceEngine::new(p.clone());
        let mut acc = 0.0;
        for fmt in FpFormat::LADDER {
            acc += e.run_nar(&cfg, 1024, fmt).throughput;
            acc += e.run_ar_step(&cfg, 1024, fmt).throughput;
        }
        acc
    });
    common::report_timing("engine::full-ladder(gpt-j)", t);

    // PJRT execute path (skipped gracefully when artifacts are absent).
    match Runtime::new() {
        Ok(mut rt) => {
            let args = rt.manifest_args("kernel_gemm_256").unwrap();
            rt.load("kernel_gemm_256").unwrap();
            let (t, _) = common::time_median(20, || {
                rt.load("kernel_gemm_256").unwrap().run(&args).unwrap()
            });
            common::report_timing("runtime::execute(kernel_gemm_256)", t);

            let args = rt.manifest_args("gpt_block_ar_tiny").unwrap();
            rt.load("gpt_block_ar_tiny").unwrap();
            let (t, _) = common::time_median(20, || {
                rt.load("gpt_block_ar_tiny").unwrap().run(&args).unwrap()
            });
            common::report_timing("runtime::execute(ar_decode_step)", t);
        }
        Err(e) => println!("(runtime benches skipped: {e})"),
    }
}
