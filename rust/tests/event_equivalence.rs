//! Event-core equivalence: the event-heap run loop (`EngineMode::Event`)
//! must reproduce the legacy per-iteration loop (`EngineMode::Iteration`)
//! **bit-for-bit** — same completion order, same cycle stamps, same
//! priced work, same scheduler counters, same exact-mode percentiles —
//! across everything the scheduler can do: priority classes, aging,
//! Poisson arrivals, shared prefixes, chunked prefill, token-budget
//! mixed passes, legacy full reservation, and tp/pp shard plans. The
//! only allowed differences are the engine label and the pass-shape
//! memo counters (the iteration loop never arms the memo), which
//! `ServeReport::same_outcome` masks explicitly.

mod common;

use common::Rng;
use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{
    BatcherConfig, ContinuousBatcher, EngineMode, ServeReport, Workload,
};
use snitch_fm::model::ModelConfig;
use snitch_fm::parallel::ShardPlan;
use snitch_fm::trace::TraceSettings;

fn run_engine(
    cfg: &ModelConfig,
    p: &PlatformConfig,
    mut opts: BatcherConfig,
    w: &Workload,
    engine: EngineMode,
) -> ServeReport {
    opts.engine = engine;
    ContinuousBatcher::new(cfg, p, FpFormat::Fp32, opts).run(w)
}

/// Assert the full equivalence contract between the two engines on one
/// trace, including the invariants on the fields `same_outcome` masks.
fn assert_engines_agree(
    cfg: &ModelConfig,
    p: &PlatformConfig,
    opts: BatcherConfig,
    w: &Workload,
    label: &str,
) {
    let ev = run_engine(cfg, p, opts, w, EngineMode::Event);
    let it = run_engine(cfg, p, opts, w, EngineMode::Iteration);
    assert_eq!(ev.engine, "event");
    assert_eq!(it.engine, "iter");
    assert!(
        ev.same_outcome(&it),
        "{label}: event and iteration reports diverge\n\
         event: completed {} cycles {} work {:?}\n\
         iter:  completed {} cycles {} work {:?}",
        ev.completed,
        ev.total_cycles,
        ev.work,
        it.completed,
        it.total_cycles,
        it.work,
    );
    // The per-layer pricing memo must see the identical lookup stream:
    // pass-shape memo hits replay their per-layer lookups as credited
    // hits, so these counters cannot drift between engines.
    assert_eq!(ev.pricing_cache_hits, it.pricing_cache_hits, "{label}");
    assert_eq!(ev.pricing_cache_misses, it.pricing_cache_misses, "{label}");
    // Event accounting: one arrival per offered request, one pass event
    // per priced iteration, every pass either a memo hit or miss.
    assert_eq!(ev.arrival_events, it.arrival_events, "{label}");
    assert_eq!(ev.pass_events, it.pass_events, "{label}");
    assert_eq!(
        ev.pass_cache_hits + ev.pass_cache_misses,
        ev.pass_events,
        "{label}"
    );
    assert_eq!(it.pass_cache_hits + it.pass_cache_misses, 0, "{label}");
    // Exact-mode percentiles (all traces here are far below the sketch
    // spill limit) and the per-request detail match bitwise.
    assert!(ev.latency_sketch.is_exact(), "{label}");
    assert_eq!(ev.per_request, it.per_request, "{label}");
}

#[test]
fn event_core_matches_legacy_on_randomized_traces() {
    let p = PlatformConfig::occamy();
    let cfg = ModelConfig::tiny();
    let mut rng = Rng(0xE7E47);
    for trial in 0..14 {
        let n = rng.next(6, 24) as usize;
        let mut w = Workload::synthetic(rng.next(1, 1 << 30), n, (4, 64), (1, 16));
        if rng.next(0, 1) == 1 {
            w = w.with_shared_prefix(rng.next(16, 48), rng.next(2, 4) as usize);
        }
        if rng.next(0, 1) == 1 {
            w = w.with_priority_classes(rng.next(2, 3) as u8);
        }
        if rng.next(0, 1) == 1 {
            w = w.with_poisson_arrivals(rng.next(1, 999), rng.next(100, 5000) as f64);
        }
        let mut opts = BatcherConfig::new(rng.next(2, 6) as usize, 0);
        if rng.next(0, 1) == 1 {
            opts.prefill_chunk = rng.next(8, 32);
        }
        if rng.next(0, 1) == 1 {
            opts.token_budget = rng.next(16, 64);
        }
        if rng.next(0, 1) == 1 {
            opts.reserve_full = true;
        }
        if rng.next(0, 1) == 1 {
            opts.aging_promote_s = 0.001;
        }
        assert_engines_agree(&cfg, &p, opts, &w, &format!("trial {trial}"));
    }
}

#[test]
fn event_core_matches_legacy_under_shard_plans() {
    // tp/pp passes price through `plan_pass_cost` (rank-local layers +
    // collectives) instead of the plain mixed pricing; the pass memo
    // must stay value-transparent there too.
    let cfg = ModelConfig::tiny(); // 2 blocks, 4 heads: tp=2 and pp=2 legal
    let p = PlatformConfig::with_dies(4);
    let w = Workload::synthetic(21, 12, (8, 48), (2, 10))
        .with_poisson_arrivals(5, 1500.0);
    for (tp, pp) in [(2u32, 1u32), (1, 2), (2, 2)] {
        let mut opts = BatcherConfig::new(4, 0);
        opts.plan = ShardPlan { tp, pp, replicas: 1 };
        assert_engines_agree(&cfg, &p, opts, &w, &format!("tp={tp} pp={pp}"));
    }
}

#[test]
fn event_core_matches_legacy_under_preemption_pressure() {
    // A page pool far too small for the offered load forces admissions,
    // growth failures, and recompute preemptions; the event loop must
    // replay the exact same victim choices and requeue order.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let w = Workload::synthetic(31, 16, (32, 128), (8, 32));
    // ~1 KiB/token of KV for the tiny model in fp32: a 256 KiB pool
    // holds one or two in-flight requests of this size distribution, so
    // admission keeps failing and growth keeps evicting.
    let mut opts = BatcherConfig::new(6, 256 * 1024);
    opts.page_tokens = 8;
    assert_engines_agree(&cfg, &p, opts, &w, "preemption pressure");
}

#[test]
fn serve_stream_matches_materialized_run() {
    // The lazy arrival stream through `serve_stream` must land exactly
    // where materializing the same stream and running the event loop
    // over the queue does — full report equality, engine field included.
    let p = PlatformConfig::occamy();
    let cfg = ModelConfig::tiny();
    let opts = BatcherConfig::new(4, 0);
    let stream = Workload::stream_poisson(3, 2000.0, 40, 24, 8).with_priority_classes(2);
    let w = Workload::stream_poisson(3, 2000.0, 40, 24, 8)
        .with_priority_classes(2)
        .materialize();
    let streamed = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, opts).serve_stream(stream);
    let materialized = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, opts).run(&w);
    assert_eq!(streamed, materialized);
    assert_eq!(streamed.requests, 40);
    assert_eq!(streamed.engine, "event");
}

#[test]
fn traced_run_is_bit_identical_on_randomized_traces() {
    // Arming the recorder must be invisible to BOTH engine cores — full
    // report equality, pricing/pass-memo counters included — and the
    // recorded spans must satisfy the tiling and conservation
    // invariants. No shared prefixes and an unbounded pool here: with no
    // prefix dedup and no preemption, every prompt token is priced in
    // exactly one chunk and every generated token in exactly one pass,
    // so the trace must conserve the report's token counters exactly.
    let p = PlatformConfig::occamy();
    let cfg = ModelConfig::tiny();
    let mut rng = Rng(0x7_14CE);
    for trial in 0..8 {
        let n = rng.next(6, 20) as usize;
        let mut w = Workload::synthetic(rng.next(1, 1 << 30), n, (4, 64), (1, 16));
        if rng.next(0, 1) == 1 {
            w = w.with_priority_classes(rng.next(2, 3) as u8);
        }
        if rng.next(0, 1) == 1 {
            w = w.with_poisson_arrivals(rng.next(1, 999), rng.next(100, 5000) as f64);
        }
        let mut opts = BatcherConfig::new(rng.next(2, 6) as usize, 0);
        if rng.next(0, 1) == 1 {
            opts.prefill_chunk = rng.next(8, 32);
        }
        if rng.next(0, 1) == 1 {
            opts.token_budget = rng.next(16, 64);
        }
        for engine in [EngineMode::Event, EngineMode::Iteration] {
            opts.engine = engine;
            let label = format!("trial {trial} {engine:?}");
            let b = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, opts);
            let plain = b.run(&w);
            let (traced, rec) = b.run_traced(&w, &TraceSettings::default());
            assert_eq!(plain, traced, "{label}: the recorder must be passive");
            // Busy + stall + idle tile the makespan exactly, with no
            // overlap anywhere on the engine track.
            let acct = rec.track_accounting();
            assert_eq!(
                acct.busy + acct.stall + acct.idle,
                traced.total_cycles,
                "{label}"
            );
            assert_eq!(acct.stall, 0, "{label}: no faults injected");
            let spans = rec.track_spans();
            for pair in spans.windows(2) {
                assert!(
                    pair[0].1 <= pair[1].0,
                    "{label}: overlapping track spans {pair:?}"
                );
            }
            // Busy covers the priced work bit-exactly.
            let busy: u64 = rec.passes().iter().map(|s| s.end - s.start).sum();
            assert_eq!(busy, traced.work.cycles, "{label}");
            // Token conservation: pass spans and chunk spans each account
            // for every prefill token, decode slots for every generated
            // token, lifecycles for every completion.
            let span_prefill: u64 = rec.passes().iter().map(|s| s.prefill_tokens).sum();
            let span_decode: u64 = rec.passes().iter().map(|s| s.decode_tokens).sum();
            let chunk_tokens: u64 = rec.chunks().iter().map(|c| c.tokens).sum();
            assert_eq!(span_prefill, traced.prefill_tokens, "{label}");
            assert_eq!(chunk_tokens, traced.prefill_tokens, "{label}");
            // Budget-mode fused passes emit a request's first token from
            // the prefill-completing pass itself — no decode slot — so
            // the slot count plus those emissions covers every token.
            assert_eq!(
                span_decode + traced.fused_first_tokens,
                traced.gen_tokens,
                "{label}"
            );
            assert_eq!(
                rec.chunks().len() as u64,
                traced.prefill_chunks,
                "{label}"
            );
            let finished = rec.requests().iter().filter(|r| r.finished).count();
            assert_eq!(finished, traced.completed, "{label}");
            let gen: u64 = rec
                .requests()
                .iter()
                .filter(|r| r.finished)
                .map(|r| r.gen_tokens)
                .sum();
            assert_eq!(gen, traced.gen_tokens, "{label}");
            // The per-phase kind split plus the collective tax covers the
            // same priced work the spans do.
            let span_kinds: u64 = rec
                .passes()
                .iter()
                .map(|s| s.kind_cycles.total() + s.collective_cycles)
                .sum();
            assert_eq!(span_kinds, traced.work.cycles, "{label}");
        }
    }
}

#[test]
fn traced_run_is_passive_under_preemption_pressure() {
    // The starved-pool trace from above, now recorded: preemption and
    // re-admission reopen lifecycle spans, and every preemption leaves
    // exactly one instant marker. Token conservation does not hold here
    // (recomputed prefills price twice) — passivity and tiling must.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let w = Workload::synthetic(31, 16, (32, 128), (8, 32));
    let mut opts = BatcherConfig::new(6, 256 * 1024);
    opts.page_tokens = 8;
    for engine in [EngineMode::Event, EngineMode::Iteration] {
        opts.engine = engine;
        let b = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, opts);
        let plain = b.run(&w);
        let (traced, rec) = b.run_traced(&w, &TraceSettings::default());
        assert_eq!(plain, traced, "{engine:?}: the recorder must be passive");
        assert!(traced.preemptions > 0, "{engine:?}: the pool must starve");
        let preempt_markers = rec
            .markers()
            .iter()
            .filter(|m| m.label == "preempt")
            .count() as u64;
        assert_eq!(preempt_markers, traced.preemptions, "{engine:?}");
        let acct = rec.track_accounting();
        assert_eq!(
            acct.busy + acct.stall + acct.idle,
            traced.total_cycles,
            "{engine:?}"
        );
        let finished = rec.requests().iter().filter(|r| r.finished).count();
        assert_eq!(finished, traced.completed, "{engine:?}");
    }
}

#[test]
fn idle_gaps_cost_no_passes() {
    // Sparse arrivals (one request every ~10 ms of simulated time, each
    // finishing long before the next lands): the event core must price
    // exactly the passes the requests need — the idle wall-clock between
    // arrivals shows up in total_cycles but in no per-pass counter — and
    // still agree with the legacy loop bit-for-bit.
    let p = PlatformConfig::occamy();
    let cfg = ModelConfig::tiny();
    let w = Workload::uniform(8, 16, 4).with_poisson_arrivals(9, 100.0);
    let opts = BatcherConfig::new(4, 0);
    assert_engines_agree(&cfg, &p, opts, &w, "sparse arrivals");
    let ev = run_engine(&cfg, &p, opts, &w, EngineMode::Event);
    // Uniform lengths + batch-of-one service: after the first request's
    // passes are priced, every later request replays memoized shapes.
    assert!(ev.pass_cache_hits > 0, "repeat shapes must hit the memo");
    assert!(
        ev.pass_cache_misses < ev.pass_events / 2,
        "uniform sparse trace should be memo-dominated: {} misses / {} passes",
        ev.pass_cache_misses,
        ev.pass_events
    );
}
