//! Layer -> platform scheduling and pricing (paper Sec. V).
//!
//! Maps each [`Layer`] of a block onto the kernel timing models, honoring
//! the paper's fusion decisions: the out-projection uses the fused
//! concat+linear (tree reduction), GELU is fused with mlp-up, and fused
//! inputs skip their HBM read.

use std::collections::HashMap;

use crate::arch::{FpFormat, MemLevel, PlatformConfig};
use crate::kernels;
use crate::kernels::gemm::OperandHome;
use crate::model::{block_layers, Layer, LayerKind, Mode, ModelConfig};
use crate::sim::KernelCost;

/// Cost of one layer on the platform.
pub fn layer_cost(layer: &Layer, fmt: FpFormat, platform: &PlatformConfig) -> KernelCost {
    match layer.kind {
        LayerKind::Gemm => {
            let home = OperandHome {
                a: if layer.fused_input { MemLevel::Spm } else { MemLevel::Hbm },
                b: MemLevel::Hbm,
                c: MemLevel::Hbm,
            };
            kernels::gemm_cost(layer.m, layer.k, layer.n, fmt, platform, home)
        }
        LayerKind::FlashAttention => kernels::flash_attention_cost(
            layer.m, // heads
            layer.n, // sq
            layer.skv,
            layer.k, // p
            fmt,
            layer.causal,
            platform,
        ),
        LayerKind::FusedConcatLinear => {
            if platform.features.cluster_to_cluster {
                kernels::fused_concat_linear_cost(
                    layer.m,
                    layer.k / cfg_p_guard(layer),
                    cfg_p_guard(layer),
                    layer.n,
                    fmt,
                    platform,
                )
            } else {
                kernels::unfused_concat_linear_cost(
                    layer.m,
                    layer.k / cfg_p_guard(layer),
                    cfg_p_guard(layer),
                    layer.n,
                    fmt,
                    platform,
                )
            }
        }
        LayerKind::Layernorm => kernels::layernorm_cost(layer.m, layer.k, fmt, platform),
        LayerKind::Gelu => {
            kernels::gelu_cost(layer.m, layer.k, fmt, layer.fused_input, platform)
        }
    }
}

/// The layer carries K = H*P for the fused layer; recover P from the
/// stashed `skv=0,causal=false` convention: P is encoded as gcd-ish via
/// the schedule builder storing heads in `m`? No — the fused layer's
/// `k` is H*P and the head granularity only affects how K splits across
/// clusters. We use P = K / heads with heads inferred from the standard
/// 16/12-head configs via the largest power-of-two-ish divisor <= 16.
/// To stay exact, `block_cost` passes P explicitly; this fallback exists
/// for direct `layer_cost` calls on synthetic layers.
fn cfg_p_guard(layer: &Layer) -> u64 {
    // Default head granularity: 16 heads (all paper models except ViT-B).
    let heads = if layer.k % 16 == 0 { 16 } else { 12 };
    (layer.k / heads).max(1)
}

/// Per-block and per-model cost summary.
#[derive(Debug, Clone, Default)]
pub struct ModelCost {
    /// Total cycles for one forward pass (NAR) or one token (AR).
    pub cycles: u64,
    /// Aggregate kernel costs by class.
    pub by_kind: HashMap<LayerKind, KernelCost>,
    /// Aggregate kernel costs by layer label ("q-proj", "mlp-up", ...).
    pub by_label: HashMap<&'static str, KernelCost>,
    /// Total cost.
    pub total: KernelCost,
    /// Blocks priced.
    pub blocks: u64,
}

impl ModelCost {
    /// Fraction of cycles spent in `kind`.
    pub fn fraction(&self, kind: LayerKind) -> f64 {
        if self.total.cycles == 0 {
            return 0.0;
        }
        self.by_kind.get(&kind).map(|c| c.cycles as f64).unwrap_or(0.0)
            / self.total.cycles as f64
    }
}

/// Cost of one transformer block.
pub fn block_cost(
    cfg: &ModelConfig,
    mode: Mode,
    s: u64,
    kv_len: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    let mut out = ModelCost { blocks: 1, ..Default::default() };
    for layer in block_layers(cfg, mode, s, kv_len) {
        let c = match layer.kind {
            // The fused layer needs exact head granularity from the config.
            LayerKind::FusedConcatLinear => {
                if platform.features.cluster_to_cluster {
                    kernels::fused_concat_linear_cost(
                        layer.m, cfg.heads, cfg.p, layer.n, fmt, platform,
                    )
                } else {
                    kernels::unfused_concat_linear_cost(
                        layer.m, cfg.heads, cfg.p, layer.n, fmt, platform,
                    )
                }
            }
            _ => layer_cost(&layer, fmt, platform),
        };
        let slot = out.by_kind.entry(layer.kind).or_default();
        *slot = slot.then(c);
        let slot = out.by_label.entry(layer.label).or_default();
        *slot = slot.then(c);
        out.total = out.total.then(c);
    }
    out.cycles = out.total.cycles;
    out
}

/// Cost of a full model pass: `blocks` x block cost. In AR mode, `s` is
/// the current KV length (per-token cost at that point in the sequence).
pub fn model_cost(
    cfg: &ModelConfig,
    mode: Mode,
    s: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    let (bs, kv) = match mode {
        Mode::Nar => (s, 0),
        Mode::Ar => (1, s),
    };
    let one = block_cost(cfg, mode, bs, kv, fmt, platform);
    let mut out = ModelCost { blocks: cfg.blocks, ..Default::default() };
    for (k, v) in &one.by_kind {
        out.by_kind.insert(*k, v.repeat(cfg.blocks));
    }
    for (k, v) in &one.by_label {
        out.by_label.insert(*k, v.repeat(cfg.blocks));
    }
    out.total = one.total.repeat(cfg.blocks);
    out.cycles = out.total.cycles;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn gemm_dominates_nar_latency() {
        // Fig. 10: GEMMs are ~66% of GPT-J FP32 NAR latency.
        let cfg = ModelConfig::gpt_j();
        let mc = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp32, &occ());
        let gemm_frac = mc.fraction(LayerKind::Gemm)
            + mc.fraction(LayerKind::FusedConcatLinear);
        assert!(gemm_frac > 0.5, "gemm fraction {gemm_frac}");
        let act_frac = mc.fraction(LayerKind::Layernorm) + mc.fraction(LayerKind::Gelu);
        assert!(act_frac < 0.2, "activations {act_frac}");
    }

    #[test]
    fn ar_gemm_fraction_higher_than_nar() {
        // Fig. 10: AR is even more GEMM-dominated (97% FP32) — the plain
        // GEMV weight streaming eats the token latency.
        let cfg = ModelConfig::gpt_j();
        let nar = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp32, &occ());
        let ar = model_cost(&cfg, Mode::Ar, 1024, FpFormat::Fp32, &occ());
        let f = |mc: &ModelCost| mc.fraction(LayerKind::Gemm);
        assert!(f(&ar) > f(&nar), "ar {} vs nar {}", f(&ar), f(&nar));
        assert!(f(&ar) > 0.85, "ar gemv share {}", f(&ar));
    }

    #[test]
    fn fa_fraction_grows_at_fp8() {
        // Fig. 10: FA-2's relative share grows FP32 -> FP8 (FP32 softmax).
        let cfg = ModelConfig::gpt_j();
        let f32c = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp32, &occ());
        let f8c = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp8, &occ());
        assert!(
            f8c.fraction(LayerKind::FlashAttention)
                > f32c.fraction(LayerKind::FlashAttention),
            "fp8 {} vs fp32 {}",
            f8c.fraction(LayerKind::FlashAttention),
            f32c.fraction(LayerKind::FlashAttention)
        );
    }

    #[test]
    fn model_cost_scales_with_blocks() {
        let mut cfg = ModelConfig::vit_b();
        let one = model_cost(&cfg, Mode::Nar, 197, FpFormat::Fp32, &occ());
        cfg.blocks *= 2;
        let two = model_cost(&cfg, Mode::Nar, 197, FpFormat::Fp32, &occ());
        assert_eq!(two.cycles, 2 * one.cycles);
    }

    #[test]
    fn block_cost_covers_all_kinds() {
        let cfg = ModelConfig::vit_b();
        let bc = block_cost(&cfg, Mode::Nar, 197, 0, FpFormat::Fp32, &occ());
        for kind in [
            LayerKind::Gemm,
            LayerKind::FlashAttention,
            LayerKind::FusedConcatLinear,
            LayerKind::Layernorm,
            LayerKind::Gelu,
        ] {
            assert!(bc.by_kind.contains_key(&kind), "{kind:?} missing");
        }
        let sum: u64 = bc.by_kind.values().map(|c| c.cycles).sum();
        assert_eq!(sum, bc.cycles);
    }
}
