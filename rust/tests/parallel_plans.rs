//! Integration and property tests for the multi-die parallelism
//! subsystem: collective-pricing invariants (symmetry, monotonicity),
//! shard-plan degeneracy (the single plan is bit-identical to the
//! single-engine paths), planner selection, and the replica router.

mod common;

use common::Rng;
use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::schedule::block_cost_batched;
use snitch_fm::coordinator::{BatcherConfig, InferenceEngine, Request, Workload};
use snitch_fm::model::{Mode, ModelConfig};
use snitch_fm::parallel::{
    all_gather_cost, all_reduce_cost, best_plans, disagg_split_feasible, p2p_cost, plan_cost,
    rank_fleet_splits, reduce_scatter_cost, serve_replicated, sharded_block_cost, Algorithm,
    Objective, RoutePolicy, ShardPlan,
};

const CASES: usize = 100;

#[test]
fn ring_all_reduce_symmetric_in_rank_order() {
    // The collective's cost may depend on the rank COUNT only: any
    // permutation (and any choice) of die ids prices identically.
    let p = PlatformConfig::with_dies(8);
    let mut rng = Rng(0xD1E5);
    for _ in 0..CASES {
        let n = rng.next(2, 8) as u32;
        let bytes = rng.next(1, 1 << 22);
        let fmt = rng.pick(&[FpFormat::Fp32, FpFormat::Fp16, FpFormat::Fp8]);
        let forward: Vec<u32> = (0..n).collect();
        let mut reversed = forward.clone();
        reversed.reverse();
        // A rotated id window exercises non-zero-based rank sets.
        let shifted: Vec<u32> = (0..n).map(|i| (i + 8 - n) % 8).collect();
        for alg in [Algorithm::Ring, Algorithm::Tree, Algorithm::Auto] {
            let a = all_reduce_cost(bytes, &forward, alg, fmt, &p);
            assert_eq!(a, all_reduce_cost(bytes, &reversed, alg, fmt, &p));
            assert_eq!(a, all_reduce_cost(bytes, &shifted, alg, fmt, &p));
        }
    }
}

#[test]
fn collective_cost_monotone_in_payload() {
    let p = PlatformConfig::with_dies(8);
    let mut rng = Rng(0xB17E5);
    for _ in 0..CASES {
        let n = rng.next(2, 8) as u32;
        let ranks: Vec<u32> = (0..n).collect();
        let small = rng.next(1, 1 << 20);
        let big = small + rng.next(1 << 12, 1 << 22);
        let fmt = rng.pick(&[FpFormat::Fp32, FpFormat::Fp8]);
        for alg in [Algorithm::Ring, Algorithm::Tree] {
            let a = all_reduce_cost(small, &ranks, alg, fmt, &p);
            let b = all_reduce_cost(big, &ranks, alg, fmt, &p);
            assert!(a.cycles <= b.cycles, "{alg:?} n={n} {small} vs {big}");
            assert!(a.d2d_bytes < b.d2d_bytes);
        }
        assert!(
            reduce_scatter_cost(small, &ranks, fmt, &p).cycles
                <= reduce_scatter_cost(big, &ranks, fmt, &p).cycles
        );
        assert!(
            all_gather_cost(small, &ranks, &p).cycles
                <= all_gather_cost(big, &ranks, &p).cycles
        );
        assert!(p2p_cost(small, &p).cycles <= p2p_cost(big, &p).cycles);
    }
}

#[test]
fn ring_all_reduce_monotone_in_rank_count() {
    // More ranks move more total bytes per die (2B(n-1)/n) and pay more
    // per-step latency, so the ring cost grows strictly with the count.
    let p = PlatformConfig::with_dies(16);
    let mut rng = Rng(0x4A11);
    for _ in 0..CASES {
        let bytes = rng.next(1, 1 << 22);
        let fmt = rng.pick(&[FpFormat::Fp32, FpFormat::Fp8]);
        let mut prev = 0u64;
        for n in 2..=16u32 {
            let ranks: Vec<u32> = (0..n).collect();
            let c = all_reduce_cost(bytes, &ranks, Algorithm::Ring, fmt, &p);
            assert!(
                c.cycles > prev,
                "ring n={n} bytes={bytes}: {} !> {prev}",
                c.cycles
            );
            prev = c.cycles;
        }
        // The tree grows with its level count (non-strict within a level
        // plateau: 5..=8 ranks share ceil(log2 n) = 3).
        let mut prev = 0u64;
        for n in 2..=16u32 {
            let ranks: Vec<u32> = (0..n).collect();
            let c = all_reduce_cost(bytes, &ranks, Algorithm::Tree, fmt, &p);
            assert!(c.cycles >= prev, "tree n={n} bytes={bytes}");
            prev = c.cycles;
        }
    }
}

#[test]
fn sharded_tp1_pricing_bit_identical_to_block_cost_batched() {
    // The acceptance property: the degenerate shard plan reproduces the
    // existing pricing exactly, across modes, shapes, and precisions.
    let p = PlatformConfig::occamy();
    let mut rng = Rng(0x5EED);
    for model in [ModelConfig::tiny(), ModelConfig::gpt_j(), ModelConfig::vit_b()] {
        for _ in 0..20 {
            let b = rng.next(1, 8);
            let s = rng.next(1, 512);
            let kv = rng.next(0, 1024);
            let fmt = rng.pick(&[FpFormat::Fp32, FpFormat::Fp16, FpFormat::Fp8]);
            for (mode, s, kv) in [(Mode::Nar, s, kv), (Mode::Ar, 1, kv)] {
                let sharded = sharded_block_cost(&model, 1, mode, b, s, kv, fmt, &p);
                let batched = block_cost_batched(&model, mode, b, s, kv, fmt, &p).total;
                assert_eq!(sharded, batched, "{} {mode:?} b={b} s={s} kv={kv}", model.name);
            }
        }
    }
}

#[test]
fn planner_objectives_disagree_and_both_beat_single() {
    let cfg = ModelConfig::gpt_j();
    let p = PlatformConfig::with_dies(4);
    let fmt = FpFormat::Fp8;
    let by_tp = best_plans(&cfg, fmt, &p, Mode::Ar, 8, 1024, Objective::Latency);
    let by_thr = best_plans(&cfg, fmt, &p, Mode::Ar, 8, 1024, Objective::Throughput);
    let single_lat = by_tp
        .iter()
        .find(|r| r.plan == ShardPlan::single())
        .unwrap()
        .cost
        .token_latency_cycles;
    let single_thr = by_thr
        .iter()
        .find(|r| r.plan == ShardPlan::single())
        .unwrap()
        .cost
        .tokens_per_s;
    assert!(by_tp[0].cost.token_latency_cycles < single_lat);
    assert!(by_thr[0].cost.tokens_per_s > single_thr);
    // Latency shards the weight stream; throughput replicates engines.
    assert!(by_tp[0].plan.tp > 1);
    assert_eq!(by_thr[0].plan.replicas, 4);
}

#[test]
fn router_single_replica_bit_identical_to_serve_with() {
    // Acceptance: ShardPlan { tp: 1, pp: 1, replicas: 1 } through the
    // router reproduces today's serve metrics bit-for-bit.
    let cfg = ModelConfig::tiny();
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let w = Workload::synthetic(7, 16, (8, 64), (2, 12))
        .with_shared_prefix(32, 4)
        .with_poisson_arrivals(9, 500.0);
    let mut opts = BatcherConfig::new(4, 0);
    opts.prefill_chunk = 16;
    let direct = e.serve_with(&cfg, &w, opts, FpFormat::Fp32);
    let routed = e.serve_replicated(
        &cfg,
        &w,
        opts,
        FpFormat::Fp32,
        1,
        RoutePolicy::PrefixAffinity,
    );
    assert_eq!(routed.replicas, 1);
    assert_eq!(routed.assigned, vec![16]);
    let m = &routed.merged;
    assert_eq!(m.total_cycles, direct.total_cycles);
    assert_eq!(m.completed, direct.completed);
    assert_eq!(m.tokens_per_s, direct.tokens_per_s);
    assert_eq!(m.decode_tokens_per_s, direct.decode_tokens_per_s);
    assert_eq!(m.ttft_p50_s, direct.ttft_p50_s);
    assert_eq!(m.ttft_p99_s, direct.ttft_p99_s);
    assert_eq!(m.latency_p99_s, direct.latency_p99_s);
    assert_eq!(m.prefill_tokens, direct.prefill_tokens);
    assert_eq!(m.prefix_hit_tokens, direct.prefix_hit_tokens);
    assert_eq!(m.peak_kv_bytes, direct.peak_kv_bytes);
    assert_eq!(m.preemptions, direct.preemptions);
    assert_eq!(m.per_request.len(), direct.per_request.len());
}

#[test]
fn router_replicas_serve_everything_and_cut_wall_clock() {
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(4);
    let e = InferenceEngine::new(p);
    // Closed-loop heavy load: a single engine serializes, replicas split.
    let w = Workload::synthetic(3, 32, (16, 96), (4, 16));
    let opts = BatcherConfig::new(4, 0);
    let single = e.serve_with(&cfg, &w, opts, FpFormat::Fp32);
    let fleet = e.serve_replicated(
        &cfg,
        &w,
        opts,
        FpFormat::Fp32,
        4,
        RoutePolicy::JoinShortestQueue,
    );
    assert_eq!(fleet.merged.completed, 32);
    assert_eq!(fleet.merged.gen_tokens, w.total_gen_tokens());
    assert_eq!(fleet.assigned.iter().sum::<usize>(), 32);
    assert!(fleet.per_replica.iter().all(|r| !r.per_request.is_empty()));
    assert!(
        fleet.merged.total_seconds < single.total_seconds,
        "4 replicas must finish sooner: {} !< {}",
        fleet.merged.total_seconds,
        single.total_seconds
    );
    assert!(fleet.merged.tokens_per_s > single.tokens_per_s);
    // Budget accounting spans the fleet.
    assert_eq!(
        fleet.merged.kv_budget_bytes,
        fleet.per_replica.iter().map(|r| r.kv_budget_bytes).sum::<u64>()
    );
}

#[test]
fn prefix_affinity_beats_jsq_hit_rate_on_shared_prefix_trace() {
    let cfg = ModelConfig::tiny();
    let e = InferenceEngine::new(PlatformConfig::with_dies(4));
    // 8 templates x 4 requests each, all offered at once (heavy load):
    // JSQ round-robins and splits every group across the dies (zero
    // sharing within any replica), while affinity keeps each group on
    // its template's home replica, where the admission probe and the
    // mid-prefill re-probe deduplicate the template.
    let w = Workload::uniform(32, 24, 6).with_shared_prefix(64, 4);
    let opts = BatcherConfig::new(4, 0);
    let jsq = e.serve_replicated(
        &cfg,
        &w,
        opts,
        FpFormat::Fp32,
        4,
        RoutePolicy::JoinShortestQueue,
    );
    let aff = e.serve_replicated(
        &cfg,
        &w,
        opts,
        FpFormat::Fp32,
        4,
        RoutePolicy::PrefixAffinity,
    );
    assert_eq!(jsq.merged.completed, 32);
    assert_eq!(aff.merged.completed, 32);
    assert!(
        aff.merged.prefix_hit_rate > jsq.merged.prefix_hit_rate,
        "affinity routing must beat JSQ on hit rate: {} !> {}",
        aff.merged.prefix_hit_rate,
        jsq.merged.prefix_hit_rate
    );
    // Both serve the same tokens; conservation holds fleet-wide.
    assert_eq!(aff.merged.gen_tokens, jsq.merged.gen_tokens);
    assert_eq!(
        aff.merged.prefill_tokens + aff.merged.prefix_hit_tokens,
        w.total_prompt_tokens()
    );
}

#[test]
fn serve_single_plan_bit_identical_across_die_counts() {
    // The serving parity anchor: growing the package's die count and
    // threading the (degenerate) shard plan through the batcher must not
    // move a single bit of the single-engine serve report — `serve --tp 1
    // --pp 1` (and omitted flags) IS today's report.
    let cfg = ModelConfig::tiny();
    let w = Workload::synthetic(5, 12, (8, 64), (2, 10))
        .with_shared_prefix(32, 3)
        .with_poisson_arrivals(7, 200.0);
    let mut opts = BatcherConfig::new(4, 0);
    opts.prefill_chunk = 16;
    opts.token_budget = 24;
    let single_die = InferenceEngine::new(PlatformConfig::occamy())
        .serve_with(&cfg, &w, opts, FpFormat::Fp32);
    let mut explicit = opts;
    explicit.plan = ShardPlan { tp: 1, pp: 1, replicas: 1 };
    let multi_die = InferenceEngine::new(PlatformConfig::with_dies(4))
        .serve_with(&cfg, &w, explicit, FpFormat::Fp32);
    assert_eq!(multi_die.total_cycles, single_die.total_cycles);
    assert_eq!(multi_die.completed, single_die.completed);
    assert_eq!(multi_die.kv_budget_bytes, single_die.kv_budget_bytes);
    assert_eq!(multi_die.peak_kv_bytes, single_die.peak_kv_bytes);
    assert_eq!(multi_die.prefill_tokens, single_die.prefill_tokens);
    assert_eq!(multi_die.prefix_hit_tokens, single_die.prefix_hit_tokens);
    assert_eq!(multi_die.gen_tokens, single_die.gen_tokens);
    assert_eq!(multi_die.tokens_per_s, single_die.tokens_per_s);
    assert_eq!(multi_die.decode_tokens_per_s, single_die.decode_tokens_per_s);
    assert_eq!(multi_die.ttft_p50_s, single_die.ttft_p50_s);
    assert_eq!(multi_die.ttft_p99_s, single_die.ttft_p99_s);
    assert_eq!(multi_die.latency_p99_s, single_die.latency_p99_s);
    assert_eq!(multi_die.budget_utilization, single_die.budget_utilization);
    assert_eq!(multi_die.fused_first_tokens, single_die.fused_first_tokens);
    assert_eq!(multi_die.work, single_die.work);
    assert_eq!((multi_die.tp, multi_die.pp), (1, 1));
    assert_eq!(multi_die.collective_cycles, 0);
    assert_eq!(multi_die.d2d_bytes, 0);
    for (a, b) in multi_die.per_request.iter().zip(&single_die.per_request) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.ttft_s, b.ttft_s);
        assert_eq!(a.latency_s, b.latency_s);
    }
}

#[test]
fn sharded_serve_collectives_match_the_analytic_count() {
    // A fully uniform closed-loop trace makes every serve pass
    // predictable: 4 monolithic 64-token prefill passes, then 8 lockstep
    // decode steps of 4 rows each. The serve report's collective cycles
    // and d2d bytes must equal the analytic per-pass collective prices —
    // the same numbers `plan_cost` charges.
    let cfg = ModelConfig::tiny(); // 4 heads, ff=128: tp=2 splits exactly
    let p = PlatformConfig::with_dies(2);
    let fmt = FpFormat::Fp32;
    let plan = ShardPlan { tp: 2, pp: 1, replicas: 1 };
    let w = Workload::uniform(4, 64, 8);
    let budget = Request::new(0, 64, 8).kv_bytes(&cfg) * 8;
    let mut opts = BatcherConfig::new(4, budget);
    opts.plan = plan;
    let r = InferenceEngine::new(p.clone()).serve_with(&cfg, &w, opts, fmt);
    assert_eq!(r.completed, 4);
    assert_eq!(r.prefill_chunks, 4, "monolithic prefill: one pass per prompt");
    assert_eq!(r.decode_steps, 8, "lockstep decode: one step per generated token");
    let ranks = [0u32, 1];
    let ar = |rows: u64| {
        all_reduce_cost(rows * cfg.e * fmt.bytes(), &ranks, Algorithm::Auto, fmt, &p)
    };
    // Two all-reduces per block, every block, every pass.
    let expected_cycles = 4 * cfg.blocks * 2 * ar(64).cycles
        + 8 * cfg.blocks * 2 * ar(4).cycles;
    assert_eq!(r.collective_cycles, expected_cycles);
    // plan_cost's analytic d2d for the same passes (its layers move no
    // d2d traffic, so the total IS the collective count).
    let prefill_d2d = plan_cost(&cfg, plan, Mode::Nar, 1, 64, fmt, &p).total.d2d_bytes;
    let decode_d2d = plan_cost(&cfg, plan, Mode::Ar, 4, 64, fmt, &p).total.d2d_bytes;
    assert_eq!(r.d2d_bytes, 4 * prefill_d2d + 8 * decode_d2d);
    assert!(r.collective_cycles > 0 && r.collective_cycles < r.total_cycles);
}

#[test]
fn sharded_fleet_routes_replica_groups_end_to_end() {
    // Two tp=2 replica groups on a 4-die package: the router splits the
    // trace, every group executes its shard plan (nonzero collectives on
    // each), and the merged fleet view sums the raw collective counters.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(4);
    let e = InferenceEngine::new(p);
    let w = Workload::synthetic(3, 24, (16, 96), (4, 16));
    let mut opts = BatcherConfig::new(4, 0);
    opts.plan = ShardPlan { tp: 2, pp: 1, replicas: 1 };
    let fleet = e.serve_replicated(
        &cfg,
        &w,
        opts,
        FpFormat::Fp32,
        2,
        RoutePolicy::JoinShortestQueue,
    );
    assert_eq!(fleet.merged.completed, 24);
    assert_eq!(fleet.merged.gen_tokens, w.total_gen_tokens());
    assert_eq!((fleet.merged.tp, fleet.merged.pp), (2, 1));
    for rep in &fleet.per_replica {
        assert!(rep.collective_cycles > 0, "every group pays the TP tax");
        assert!(rep.d2d_bytes > 0);
    }
    assert_eq!(
        fleet.merged.collective_cycles,
        fleet.per_replica.iter().map(|r| r.collective_cycles).sum::<u64>()
    );
    assert_eq!(
        fleet.merged.d2d_bytes,
        fleet.per_replica.iter().map(|r| r.d2d_bytes).sum::<u64>()
    );
}

#[test]
fn merged_rates_recomputed_from_raw_counters() {
    // Regression for the router-merge audit: derived fleet rates used to
    // be cycle-weighted means of per-replica *rates*, which drifts from
    // the counter-true value whenever replicas are uneven. Every rate
    // must now equal the exact recompute from the merged raw counters.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(3);
    let e = InferenceEngine::new(p.clone());
    // Deliberately lopsided trace: wide prompt/gen spread so the three
    // replicas end up with different budget fills and memo hit rates.
    let w = Workload::synthetic(9, 21, (8, 160), (2, 24)).with_poisson_arrivals(4, 80.0);
    let mut opts = BatcherConfig::new(3, 0);
    opts.prefill_chunk = 16;
    opts.token_budget = 24;
    let fleet =
        e.serve_replicated(&cfg, &w, opts, FpFormat::Fp32, 3, RoutePolicy::JoinShortestQueue);
    let m = &fleet.merged;
    // Conservation: splitting one trace across replicas loses nothing.
    assert_eq!(m.requests, w.len());
    assert_eq!(m.completed, w.len());
    assert_eq!(m.gen_tokens, w.total_gen_tokens());
    assert_eq!(m.prefill_tokens + m.prefix_hit_tokens, w.total_prompt_tokens());
    for (field, total) in [
        (m.budget_tokens, fleet.per_replica.iter().map(|r| r.budget_tokens).sum::<u64>()),
        (m.decode_tokens, fleet.per_replica.iter().map(|r| r.decode_tokens).sum()),
        (m.pricing_cache_hits, fleet.per_replica.iter().map(|r| r.pricing_cache_hits).sum()),
    ] {
        assert_eq!(field, total);
    }
    // Exact recomputes from merged raw counters (never averaged rates).
    assert_eq!(
        m.budget_utilization,
        m.budget_tokens as f64 / (m.budget_iterations * m.token_budget) as f64
    );
    assert_eq!(
        m.pricing_cache_hit_rate,
        m.pricing_cache_hits as f64 / (m.pricing_cache_hits + m.pricing_cache_misses) as f64
    );
    assert_eq!(
        m.avg_batch_occupancy,
        m.decode_tokens as f64 / m.decode_steps as f64
    );
    assert_eq!(
        m.fpu_utilization,
        snitch_fm::metrics::fpu_utilization(&m.work, FpFormat::Fp32, &p)
    );
    assert_eq!(m.hbm_gb, m.work.hbm_bytes() as f64 / 1e9);
    // The replicas genuinely disagree on at least one rate, so a weighted
    // mean of rates could not have produced the counter-true value.
    let utils: Vec<f64> =
        fleet.per_replica.iter().map(|r| r.budget_utilization).collect();
    assert!(
        utils.iter().any(|u| (u - utils[0]).abs() > 1e-9),
        "trace must load the replicas unevenly: {utils:?}"
    );
}

#[test]
fn replica_kv_budgets_are_independent() {
    // Each replica prices against its own die's budget: a pool sized for
    // ~2 requests per replica still serves 4x that across the fleet
    // without the budget ever being exceeded on any die.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(2);
    let w = Workload::uniform(8, 16, 8);
    let one = w.requests[0].kv_bytes(&cfg);
    let opts = BatcherConfig::new(4, 2 * one);
    let fleet = serve_replicated(
        &cfg,
        &p,
        FpFormat::Fp32,
        opts,
        &w,
        2,
        RoutePolicy::JoinShortestQueue,
    );
    assert_eq!(fleet.merged.completed, 8);
    for r in &fleet.per_replica {
        assert!(r.peak_kv_bytes <= 2 * one, "per-die budget respected");
    }
    assert!(fleet.merged.peak_kv_bytes <= 4 * one, "fleet peak sums the dies");
}

#[test]
fn disagg_auto_feasibility_covers_the_degenerate_die_budgets() {
    // Regression for `serve --disagg auto` graceful degradation: the two
    // budgets that used to bail the CLI — one die, and a tp*pp product
    // already consuming every offered die — are exactly the infeasible
    // cases; any budget with room for a second group (or no explicit
    // budget at all) stays on the auto-split path.
    assert!(!disagg_split_feasible(1, 1, 1), "one die cannot split");
    assert!(!disagg_split_feasible(2, 2, 4), "tp*pp == dies leaves no second group");
    assert!(!disagg_split_feasible(2, 1, 3), "a fractional second group does not fit");
    assert!(disagg_split_feasible(1, 1, 2), "two dies hold {{1, 1}}");
    assert!(disagg_split_feasible(2, 2, 8), "two tp=2 pp=2 groups fit in 8 dies");
    assert!(disagg_split_feasible(4, 2, 0), "no explicit budget: the package grows");
}

#[test]
fn fleet_split_ranking_never_returns_empty_for_a_clamped_budget() {
    // The planner clamps the replica budget to >= 2 groups, so once the
    // feasibility gate passes, `--disagg auto` always has a best split
    // to adopt — including the degenerate budget of a single replica.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(2);
    let w = Workload::uniform(8, 32, 8);
    for budget in [1usize, 2, 3, 7] {
        let ranking = rank_fleet_splits(&cfg, FpFormat::Fp32, &p, &w, 4, budget);
        let best = ranking.splits.first().expect("clamped ranking is never empty");
        assert!(best.prefill >= 1 && best.decode >= 1);
        assert_eq!(best.prefill + best.decode, budget.max(2));
        assert!(best.rate > 0.0);
    }
}

#[test]
fn symmetric_fleet_fallback_serves_the_full_trace_on_one_die() {
    // The degraded path `--disagg auto` falls back to on a 1-die budget:
    // a single symmetric replica. It must serve the whole trace (no
    // requests lost to the infeasible split) with clean fault counters.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let w = Workload::uniform(6, 24, 6);
    let fleet = serve_replicated(
        &cfg,
        &p,
        FpFormat::Fp32,
        BatcherConfig::new(4, 0),
        &w,
        1,
        RoutePolicy::JoinShortestQueue,
    );
    assert_eq!(fleet.merged.completed, 6);
    assert!(fleet.merged.rejected.is_empty());
    assert_eq!(fleet.merged.replica_failures, 0);
    assert_eq!(fleet.merged.degraded_capacity_fraction, 0.0);
}
