//! snitch-fm CLI: run, sweep, breakdown, compare, serve, validate.
//!
//! The leader entrypoint of the Layer-3 coordinator. All timing numbers
//! come from the cycle-level platform simulator; `validate` additionally
//! executes the AOT HLO artifacts through PJRT and checks the golden
//! numerics (proving the request path needs no Python).

use anyhow::Result;

use snitch_fm::arch::{Features, FpFormat, PlatformConfig, PrecisionPolicy};
use snitch_fm::config::parse_mode;
use snitch_fm::coordinator::{
    Arrival, BatcherConfig, ClassLadder, ContinuousBatcher, FaultPlan, InferenceEngine,
    SharedPrefix, Workload,
};
use snitch_fm::model::{Mode, ModelConfig};
use snitch_fm::parallel::{
    best_plans, best_plans_policy, disagg_split_feasible, rank_fleet_splits_policy,
    serve_disaggregated_traced, serve_replicated_traced, Objective, RoutePolicy, ShardPlan,
};
use snitch_fm::report;
use snitch_fm::trace::{FleetTrace, TraceSettings, DEFAULT_METRICS_INTERVAL_US};
use snitch_fm::runtime::Runtime;
use snitch_fm::soa;
use snitch_fm::util::cli::Args;

const USAGE: &str = "\
snitch-fm — foundation-model inference on a many-tiny-core RISC-V platform

USAGE: snitch-fm <COMMAND> [FLAGS]

COMMANDS:
  run        Price one model pass on the simulated platform
             --model NAME --mode nar|ar --format FMT --seq N --clusters N
             --baseline --config FILE --csv
  sweep      Precision ladder, baseline -> fp8 (Fig. 7/8)
             --model NAME --mode nar|ar --seq N --clusters N
  breakdown  Kernel latency breakdown (Fig. 10)
             --model NAME --mode nar|ar --format FMT --seq N
  compare    SoA comparison --exp table4|h100|academic|fig1
  serve      Multi-request serving simulation: continuous batching with
             paged KV, prefix caching, chunked prefill, token-budget
             mixed passes, priority admission
             --model NAME --requests N --batch N --format FMT
             --prompt N --gen N --seed N --clusters N
             --kv-format FMT (KV-cache storage precision, narrower-or-
               equal to --format; pages, budgets, exports and disagg
               migrations shrink proportionally and each pass bills the
               dequant-on-read kernel; default: same as --format)
             --class-precision SPEC (per-priority-class compute ladder,
               e.g. hi:fp16,lo:fp8 or 0:fp16,1:bf16,lo:fp8; hi = class 0,
               lo = every other unmapped class; unmapped classes serve at
               --format; every rung must respect --kv-format's lattice)
             --kv-page-tokens N (default 16)
             --prefill-chunk N (0 = monolithic prefill)
             --token-budget N (per-iteration prefill+decode token budget
               priced as one fused pass; 0 = pass alternation)
             --shared-prefix TOKENSxFANOUT (groups of FANOUT requests
               share a TOKENS-token system prompt)
             --no-prefix-cache (disable shared-prefix page dedup)
             --arrival batch|poisson:<rate-per-s>
             --priorities N (round-robin classes, aged FCFS)
             --aging S (seconds of wait per class promotion; 0 = off)
             --reserve-full (legacy full-length KV reservation)
             --tp N --pp N (execute every replica as a tensor-parallel x
               pipeline-parallel shard group: passes price through the
               rank-local layers plus the per-iteration all-reduces and
               activation sends; default 1 1 = single-die engine)
             --plan auto (take the planner's best {tp, pp, replicas} for
               --dies N dies and --objective latency|throughput instead
               of explicit --tp/--pp/--replicas)
             --dies N (dies in the package; default: just enough for
               tp * pp * replicas)
             --replicas N (data-parallel replica groups)
             --route jsq|affinity (replica routing policy; affinity keeps
               shared-prefix groups on their template's home replica)
             --engine event|iter (event-heap run loop with pass-shape
               memoization, or the legacy per-iteration loop; reports are
               bit-identical — default event)
             --disagg off|P:D|auto (disaggregated serving: P replica
               groups run prefill only and hand each finished prompt's
               KV pages to one of D decode groups over the die-to-die
               links; auto splits the replica budget by the modeled
               best {prefill, decode} ratio; off — the default — keeps
               the symmetric fleet bit-identical to --replicas)
             --no-per-request (drop the per-request detail array from
               the report; every aggregate, percentile and counter is
               unchanged)
             --faults SPEC (seeded fault injection, comma-separated:
               fail@<s>[:r<i>] permanent replica failure with the die's
               KV pool surviving for re-export, die@<s>[:r<i>] whole-die
               failure (KV pool lost, salvaged requests recompute),
               stall@<s>:<cycles>[:r<i>] transient freeze,
               link@<s>:<fraction> d2d bandwidth degradation,
               corrupt:<p> per-migration KV corruption probability;
               off — the default — is bit-identical to no flag)
             --fault-seed N (seed for unpinned fault targets and
               corruption draws; default 0)
             --trace FILE (write a Chrome trace-event JSON of the run —
               open in Perfetto; replicas and the KV-migration path are
               processes, passes / transfers / requests are threads — and
               print a per-track accounting summary; recording is
               passive, the report is bit-identical to an untraced run)
             --metrics-interval US (gauge sampling cadence in simulated
               microseconds for --trace; default 1000)
             --json (machine-readable report)
  shard      Enumerate and rank multi-die shard plans {tp, pp, replicas}
             --model NAME --format FMT --dies N --batch N --seq N
             --mode nar|ar --objective latency|throughput --json
  validate   Execute AOT artifacts via PJRT, verify golden numerics
             --artifacts DIR
  help       Show this message

Models: vit-b vit-l vit-h gpt3-xl gpt-j tiny
Formats: fp64 fp32 fp16 bf16 fp8 fp8alt";

fn model_by_name(name: &str) -> Result<ModelConfig> {
    ModelConfig::preset(name).ok_or_else(|| anyhow::anyhow!("unknown model preset {name}"))
}

fn parse_format(s: &str) -> Result<FpFormat> {
    FpFormat::parse(s).ok_or_else(|| anyhow::anyhow!("unknown format {s}"))
}

fn default_seq(cfg: &ModelConfig, seq: u64) -> u64 {
    if seq == 0 {
        cfg.seq
    } else {
        seq
    }
}

const FLAGS: &[&str] = &[
    "model", "mode", "format", "seq", "clusters", "baseline", "config", "csv",
    "exp", "artifacts", "requests", "batch", "prompt", "gen", "seed",
    "kv-page-tokens", "prefill-chunk", "arrival", "priorities", "reserve-full",
    "aging", "json", "token-budget", "shared-prefix", "no-prefix-cache",
    "replicas", "route", "dies", "objective", "tp", "pp", "plan", "engine",
    "disagg", "no-per-request", "faults", "fault-seed", "trace",
    "metrics-interval", "kv-format", "class-precision",
];

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), FLAGS)?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("breakdown") => cmd_breakdown(&args),
        Some("compare") => cmd_compare(&args),
        Some("serve") => cmd_serve(&args),
        Some("shard") => cmd_shard(&args),
        Some("validate") => cmd_validate(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown command {other}\n\n{USAGE}"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    // Config file first, CLI overrides.
    let (cfg, platform, mode, format, seq) = if let Some(path) = args.get("config") {
        let rc = snitch_fm::config::load(std::path::Path::new(path))?;
        let cfg = rc.model.to_model()?;
        let cli_seq = args.get_u64("seq", 0)?;
        let seq = default_seq(&cfg, if cli_seq != 0 { cli_seq } else { rc.run.seq });
        (cfg, rc.platform.to_platform(), rc.run.mode()?, rc.run.format()?, seq)
    } else {
        let cfg = model_by_name(args.get_or("model", "gpt-j"))?;
        let mut platform = PlatformConfig::with_clusters(args.get_u32("clusters", 16)?);
        if args.get_bool("baseline") {
            platform.features = Features::baseline();
        }
        let seq = default_seq(&cfg, args.get_u64("seq", 0)?);
        (
            cfg,
            platform,
            parse_mode(args.get_or("mode", "nar"))?,
            parse_format(args.get_or("format", "fp32"))?,
            seq,
        )
    };
    let engine = InferenceEngine::new(platform);
    let r = match mode {
        Mode::Nar => engine.run_nar(&cfg, seq, format),
        Mode::Ar => engine.run_ar_step(&cfg, seq, format),
    };
    if args.get_bool("csv") {
        print!("{}", report::runs_csv(&[r]));
    } else {
        print!("{}", report::runs_table(&[r]));
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = model_by_name(args.get_or("model", "gpt-j"))?;
    let mode = parse_mode(args.get_or("mode", "nar"))?;
    let seq = default_seq(&cfg, args.get_u64("seq", 0)?);
    let clusters = args.get_u32("clusters", 16)?;
    let mut rows = Vec::new();
    let mut ladder = Vec::new();
    // Baseline FP64, then optimized at each precision (Fig. 7/8).
    let mut base = PlatformConfig::with_clusters(clusters);
    base.features = Features::baseline();
    let engine = InferenceEngine::new(base);
    let r = match mode {
        Mode::Nar => engine.run_nar(&cfg, seq, FpFormat::Fp64),
        Mode::Ar => engine.run_ar_step(&cfg, seq, FpFormat::Fp64),
    };
    ladder.push(("baseline fp64".to_string(), r.throughput));
    rows.push(r);
    let engine = InferenceEngine::new(PlatformConfig::with_clusters(clusters));
    for fmt in FpFormat::LADDER {
        let r = match mode {
            Mode::Nar => engine.run_nar(&cfg, seq, fmt),
            Mode::Ar => engine.run_ar_step(&cfg, seq, fmt),
        };
        ladder.push((format!("optimized {}", fmt.name()), r.throughput));
        rows.push(r);
    }
    print!("{}", report::runs_table(&rows));
    println!();
    let unit = rows[0].throughput_unit;
    print!(
        "{}",
        report::speedup_ladder(
            &format!("{} {} ladder (Fig. 7/8)", cfg.name, rows[0].mode),
            unit,
            &ladder
        )
    );
    Ok(())
}

fn cmd_breakdown(args: &Args) -> Result<()> {
    let cfg = model_by_name(args.get_or("model", "gpt-j"))?;
    let mode = parse_mode(args.get_or("mode", "nar"))?;
    let format = parse_format(args.get_or("format", "fp32"))?;
    let seq = default_seq(&cfg, args.get_u64("seq", 0)?);
    let engine = InferenceEngine::new(PlatformConfig::occamy());
    let b = engine.breakdown(&cfg, mode, seq, format);
    let mode_name = match mode {
        Mode::Nar => "nar",
        Mode::Ar => "ar",
    };
    print!(
        "{}",
        report::breakdown_table(
            &format!("{} {} {} S={seq} (Fig. 10)", cfg.name, mode_name, format.name()),
            &b
        )
    );
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    match args.get_or("exp", "table4") {
        "table4" => {
            let engine = InferenceEngine::new(PlatformConfig::occamy());
            let r = engine.run_nar(&ModelConfig::gpt3_xl(), 1024, FpFormat::Fp16);
            let ours = soa::OursRow::from_run(
                r.gflops,
                r.fpu_utilization,
                engine.platform.total_cores(),
            );
            println!("Table IV — GPT NAR FP16 (SoA: GPT2-XL fwd, ours: GPT3-XL sim)");
            println!(
                "{:<10} {:>8} {:>10} {:>14} {:>8}",
                "platform", "CUs", "TFLOPS", "TFLOPS/CU", "util%"
            );
            for s in soa::table4_soa() {
                println!(
                    "{:<10} {:>8} {:>10.2} {:>14.4} {:>8.1}",
                    s.name, s.compute_units, s.tflops, s.tflops_per_cu, s.fpu_utilization_pct
                );
            }
            println!(
                "{:<10} {:>8} {:>10.2} {:>14.4} {:>8.1}",
                "ours", ours.compute_units, ours.tflops, ours.tflops_per_cu,
                ours.fpu_utilization_pct
            );
            println!(
                "utilization advantage over best SoA: {:.2}x",
                ours.utilization_advantage()
            );
        }
        "h100" => {
            let engine = InferenceEngine::new(PlatformConfig::occamy());
            let r = engine.run_nar(&ModelConfig::vit_l(), 197, FpFormat::Fp8);
            let h = soa::h100_vit_l_fp8();
            let ours_cu = engine.platform.total_cores();
            println!("H100 vs ours — ViT-L FP8 (Sec. VII-E)");
            println!(
                "H100: {:.0} samples/s, {:.2}/CU, {:.1}/W",
                h.samples_per_s, h.samples_per_s_per_cu, h.samples_per_s_per_w
            );
            println!(
                "ours: {:.1} samples/s, {:.3}/CU, {:.1}/W",
                r.throughput,
                r.throughput / ours_cu as f64,
                r.throughput / r.power_w
            );
        }
        "academic" => {
            let engine = InferenceEngine::new(PlatformConfig::occamy());
            let rj = engine.run_nar(&ModelConfig::gpt_j(), 1024, FpFormat::Fp8);
            let w_per_pe = rj.power_w / engine.platform.total_cores() as f64;
            let at = soa::acceltran();
            println!(
                "AccelTran: {:.2} W/PE | ours: {:.3} W/PE ({:.1}x better)",
                at.watts_per_pe.unwrap(),
                w_per_pe,
                at.watts_per_pe.unwrap() / w_per_pe
            );
            let rb = engine.run_nar(&ModelConfig::vit_b(), 197, FpFormat::Fp8);
            let t = soa::tambe();
            println!(
                "Tambe et al.: {:.0} ms | ours (ViT-B FP8): {:.1} ms ({:.1}x faster)",
                t.bert_base_latency_ms.unwrap(),
                rb.seconds * 1e3,
                t.bert_base_latency_ms.unwrap() / (rb.seconds * 1e3)
            );
        }
        "fig1" => {
            use snitch_fm::kernels::{fused_concat_linear_cost, unfused_concat_linear_cost};
            let p = PlatformConfig::occamy();
            let cfg = ModelConfig::gpt_j();
            let s = 2048;
            let f = fused_concat_linear_cost(s, cfg.heads, cfg.p, cfg.e, FpFormat::Fp32, &p);
            let u = unfused_concat_linear_cost(s, cfg.heads, cfg.p, cfg.e, FpFormat::Fp32, &p);
            println!("Fig. 1 — GPT-J S=2048 concat+linear HBM traffic");
            println!("  fused (c2c reduction): {:.1} MB", f.hbm_bytes() as f64 / 1e6);
            println!("  unfused (HBM bounce):  {:.1} MB", u.hbm_bytes() as f64 / 1e6);
            println!(
                "  reduction: {:.2}x",
                u.hbm_bytes() as f64 / f.hbm_bytes() as f64
            );
        }
        other => anyhow::bail!("unknown experiment {other}"),
    }
    Ok(())
}

/// Write the recorded fleet trace as Chrome trace-event JSON and surface
/// the per-track accounting summary (stderr under `--json`, where stdout
/// must carry nothing but the report).
fn emit_trace(path: &str, fleet: &FleetTrace, json_mode: bool) -> Result<()> {
    std::fs::write(path, fleet.to_chrome_json())
        .map_err(|e| anyhow::anyhow!("--trace {path}: {e}"))?;
    let summary = format!("{}trace written to {path}\n", report::trace_summary(fleet));
    if json_mode {
        eprint!("{summary}");
    } else {
        print!("{summary}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = model_by_name(args.get_or("model", "gpt-j"))?;
    let format = parse_format(args.get_or("format", "fp8"))?;
    let requests = args.get_usize("requests", 32)?;
    let batch = args.get_usize("batch", 8)?;
    let prompt = default_seq(&cfg, args.get_u64("prompt", 0)?);
    let gen = args.get_u64("gen", 64)?;
    let seed = args.get_u64("seed", 0)?;
    anyhow::ensure!(requests > 0, "--requests must be > 0");
    anyhow::ensure!(batch > 0, "--batch must be > 0");
    let route = match args.get("route") {
        None => RoutePolicy::JoinShortestQueue,
        Some(s) => RoutePolicy::parse(s)
            .ok_or_else(|| anyhow::anyhow!("--route {s:?}: expected jsq or affinity"))?,
    };
    let clusters = args.get_u32("clusters", 16)?;
    // Decoupled precision: --kv-format narrows KV storage under the
    // serving format, --class-precision maps priority classes to compute
    // rungs. Validated here with friendly errors (the engine asserts the
    // same lattice).
    let kv_format = match args.get("kv-format") {
        None => None,
        Some(s) => Some(parse_format(s)?),
    };
    let class_precision = match args.get("class-precision") {
        None => ClassLadder::default(),
        Some(spec) => ClassLadder::parse(spec)
            .map_err(|e| anyhow::anyhow!("--class-precision {spec:?}: {e}"))?,
    };
    let policy = PrecisionPolicy {
        weights: format,
        compute: format,
        kv: kv_format.unwrap_or(format),
    };
    if let Some(err) = policy.validity_error() {
        anyhow::bail!("--kv-format: {err}");
    }
    for rung in class_precision.rungs() {
        let p = PrecisionPolicy { compute: rung, ..policy };
        if let Some(err) = p.validity_error() {
            anyhow::bail!("--class-precision: rung {}: {err}", rung.name());
        }
    }
    // The shard configuration every replica group executes: explicit
    // --tp/--pp/--replicas, or the planner's pick under --plan auto.
    let (tp, pp, replicas) = match args.get("plan") {
        None => {
            let replicas = args.get_usize("replicas", 1)?;
            anyhow::ensure!(replicas > 0, "--replicas must be > 0");
            (args.get_u32("tp", 1)?, args.get_u32("pp", 1)?, replicas)
        }
        Some("auto") => {
            let dies = args.get_u32("dies", 2)?;
            anyhow::ensure!(dies > 0, "--dies must be > 0");
            let objective = match args.get("objective") {
                None => Objective::Throughput,
                Some(s) => Objective::parse(s).ok_or_else(|| {
                    anyhow::anyhow!("--objective {s:?}: expected latency or throughput")
                })?,
            };
            // Rank on the same per-die platform the engine will serve on
            // (a non-default --clusters shifts the compute/communication
            // balance the objectives trade off).
            let mut planner_platform = PlatformConfig::with_clusters(clusters);
            planner_platform.die.dies = dies;
            let ranked = best_plans_policy(
                &cfg,
                policy,
                &planner_platform,
                Mode::Ar,
                batch as u64,
                prompt,
                objective,
            );
            let best = ranked
                .first()
                .ok_or_else(|| anyhow::anyhow!("no legal shard plan for {dies} dies"))?
                .plan;
            // stderr: `--json` consumers must see nothing but the report.
            eprintln!(
                "plan auto ({}, {dies} dies): tp={} pp={} replicas={}",
                objective.name(),
                best.tp,
                best.pp,
                best.replicas
            );
            (best.tp, best.pp, best.replicas as usize)
        }
        Some(other) => anyhow::bail!("--plan {other:?}: expected auto"),
    };
    anyhow::ensure!(tp > 0 && pp > 0, "--tp/--pp must be > 0");
    // Disaggregated prefill/decode: `P:D` dedicates P replica groups to
    // prefill and D to decode; `auto` takes the modeled best split of
    // the replica budget; `off` (default) keeps the symmetric fleet.
    #[derive(Clone, Copy)]
    enum Disagg {
        Off,
        Split(usize, usize),
        Auto,
    }
    let disagg = match args.get("disagg") {
        None | Some("off") => Disagg::Off,
        Some("auto") => Disagg::Auto,
        Some(spec) => {
            let parsed = spec.split_once(':').and_then(|(p, d)| {
                Some((p.parse::<usize>().ok()?, d.parse::<usize>().ok()?))
            });
            match parsed {
                Some((p, d)) if p >= 1 && d >= 1 => Disagg::Split(p, d),
                _ => anyhow::bail!(
                    "--disagg {spec:?}: expected off, auto, or <prefill>:<decode> \
                     with both counts >= 1"
                ),
            }
        }
    };
    // `--disagg auto` promises the modeled best {prefill, decode} split
    // of the die budget the user actually offered. When that budget
    // cannot hold two replica groups at all (one die, or tp*pp already
    // consuming every offered die), degrade to the symmetric fleet with
    // a warning instead of bailing out.
    let mut disagg_fallback: Option<String> = None;
    let disagg = match disagg {
        Disagg::Auto => {
            let offered = args.get_u32("dies", 0)?;
            if !disagg_split_feasible(tp, pp, offered) {
                let msg = format!(
                    "disagg auto fell back to the symmetric fleet: two replica groups \
                     of tp={tp} pp={pp} need {} dies, --dies {offered} offered",
                    tp * pp * 2
                );
                // stderr: `--json` consumers must see nothing but the report.
                eprintln!("{msg}");
                disagg_fallback = Some(msg);
                Disagg::Off
            } else {
                Disagg::Auto
            }
        }
        other => other,
    };
    // Replica groups the package must hold: the symmetric fleet's
    // `replicas`, the explicit split's `P + D`, or the auto split's
    // budget (the larger of --replicas and the dies the user offered).
    let fleet_groups = match disagg {
        Disagg::Off => replicas,
        Disagg::Split(p, d) => p + d,
        Disagg::Auto => {
            let from_dies = (args.get_u32("dies", 0)? / (tp * pp)) as usize;
            replicas.max(from_dies).max(2)
        }
    };
    let mut platform = PlatformConfig::with_clusters(clusters);
    // The package needs a die per rank of every replica group.
    platform.die.dies = platform
        .die
        .dies
        .max(args.get_u32("dies", 0)?)
        .max(tp * pp * fleet_groups as u32);
    let engine_plan = ShardPlan { tp, pp, replicas: 1 };
    if let Some(err) = (ShardPlan { tp, pp, replicas: fleet_groups as u32 })
        .legality_error(&cfg, &platform)
    {
        anyhow::bail!("illegal shard configuration: {err}");
    }
    let engine = InferenceEngine::new(platform);
    if engine_plan.replica_kv_budget_bytes_policy(&cfg, policy, &engine.platform) == 0 {
        anyhow::bail!(
            "{} weights at {} ({:.1} GB) exceed the {:.1} GB per-die HBM capacity \
             under tp={tp} pp={pp}; try a lower precision (--format fp8) or more dies",
            cfg.name,
            format.name(),
            cfg.weight_bytes(format) as f64 / 1e9,
            engine.platform.interconnect.hbm_capacity_bytes as f64 / 1e9,
        );
    }
    // seed 0 = uniform workload (reproducible headline numbers); any
    // other seed draws prompt/gen lengths around the requested means.
    let mut workload = if seed == 0 {
        Workload::uniform(requests, prompt, gen)
    } else {
        Workload::synthetic(
            seed,
            requests,
            ((prompt / 2).max(1), prompt.max(2) * 2),
            ((gen / 2).max(1), gen.max(2) * 2),
        )
    };
    if let Some(spec) = args.get("shared-prefix") {
        let sp = SharedPrefix::parse(spec).ok_or_else(|| {
            anyhow::anyhow!("--shared-prefix {spec:?}: expected <tokens>x<fanout>")
        })?;
        workload = workload.with_shared_prefix(sp.tokens, sp.fanout);
    }
    let classes = args.get_u64("priorities", 1)?;
    anyhow::ensure!((1..=255).contains(&classes), "--priorities must be 1..=255");
    workload = workload.with_priority_classes(classes as u8);
    let arrival = match args.get("arrival") {
        None => Arrival::Batch,
        Some(s) => Arrival::parse(s).ok_or_else(|| {
            anyhow::anyhow!("--arrival {s:?}: expected batch or poisson:<rate>")
        })?,
    };
    if let Arrival::Poisson { rate_per_s } = arrival {
        workload = workload.with_poisson_arrivals(seed ^ 0xA441_7353, rate_per_s);
    }
    let mut opts = BatcherConfig::new(batch, 0);
    opts.page_tokens = args.get_u64("kv-page-tokens", 16)?.max(1);
    opts.prefill_chunk = args.get_u64("prefill-chunk", 0)?;
    opts.token_budget = args.get_u64("token-budget", 0)?;
    opts.reserve_full = args.get_bool("reserve-full");
    opts.prefix_cache = !args.get_bool("no-prefix-cache");
    opts.aging_promote_s = args.get_f64("aging", opts.aging_promote_s)?;
    anyhow::ensure!(opts.aging_promote_s >= 0.0, "--aging must be >= 0");
    opts.plan = engine_plan;
    if let Some(s) = args.get("engine") {
        opts.engine = snitch_fm::coordinator::EngineMode::parse(s)
            .ok_or_else(|| anyhow::anyhow!("--engine {s:?}: expected event or iter"))?;
    }
    opts.per_request = !args.get_bool("no-per-request");
    opts.kv_format = kv_format;
    opts.class_precision = class_precision;
    let faults = FaultPlan::parse(args.get_or("faults", "off"), args.get_u64("fault-seed", 0)?)
        .map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
    let trace_settings = {
        let us = args.get_f64("metrics-interval", DEFAULT_METRICS_INTERVAL_US)?;
        anyhow::ensure!(us > 0.0, "--metrics-interval must be > 0");
        TraceSettings { metrics_interval_us: us }
    };
    let trace_path = args.get("trace");
    let split = match disagg {
        Disagg::Off => None,
        Disagg::Split(p, d) => Some((p, d)),
        Disagg::Auto => {
            let ranking = rank_fleet_splits_policy(
                &cfg,
                policy,
                &engine.platform,
                &workload,
                batch,
                fleet_groups,
            );
            match ranking.splits.first() {
                Some(best) => {
                    // stderr: `--json` consumers must see nothing but the report.
                    eprintln!(
                        "disagg auto ({} groups): prefill={} decode={} ({}-bound, {:.2} req/s modeled)",
                        fleet_groups, best.prefill, best.decode, best.bottleneck, best.rate
                    );
                    Some((best.prefill, best.decode))
                }
                None => {
                    let msg = format!(
                        "disagg auto fell back to the symmetric fleet: no legal \
                         {{prefill, decode}} split for {fleet_groups} groups"
                    );
                    eprintln!("{msg}");
                    disagg_fallback = Some(msg);
                    None
                }
            }
        }
    };
    if let Some((prefill, decode)) = split {
        let mut traced = None;
        let r = match trace_path {
            Some(path) => {
                let (r, fleet) = serve_disaggregated_traced(
                    &cfg,
                    &engine.platform,
                    format,
                    opts,
                    &workload,
                    prefill,
                    decode,
                    route,
                    &faults,
                    &trace_settings,
                );
                traced = Some((path, fleet));
                r
            }
            None => engine.serve_disaggregated_with_faults(
                &cfg, &workload, opts, format, prefill, decode, route, &faults,
            ),
        };
        if args.get_bool("json") {
            println!("{}", report::disagg_json(&r));
        } else {
            print!("{}", report::disagg_table(&r));
        }
        if let Some((path, fleet)) = traced {
            emit_trace(path, &fleet, args.get_bool("json"))?;
        }
        return Ok(());
    }
    if replicas > 1 || !faults.is_off() {
        let mut traced = None;
        let mut r = match trace_path {
            Some(path) => {
                let (r, fleet) = serve_replicated_traced(
                    &cfg,
                    &engine.platform,
                    format,
                    opts,
                    &workload,
                    replicas,
                    route,
                    &faults,
                    &trace_settings,
                );
                traced = Some((path, fleet));
                r
            }
            None => engine.serve_replicated_with_faults(
                &cfg, &workload, opts, format, replicas, route, &faults,
            ),
        };
        if let Some(msg) = disagg_fallback {
            r.merged.warnings.push(msg);
        }
        if args.get_bool("json") {
            println!("{}", report::router_json(&r));
        } else {
            print!("{}", report::router_table(&r));
        }
        if let Some((path, fleet)) = traced {
            emit_trace(path, &fleet, args.get_bool("json"))?;
        }
        return Ok(());
    }
    let mut traced = None;
    let mut report = match trace_path {
        Some(path) => {
            let (r, rec) = ContinuousBatcher::new(&cfg, &engine.platform, format, opts)
                .run_traced(&workload, &trace_settings);
            traced = Some((path, FleetTrace::single("replica 0", rec)));
            r
        }
        None => engine.serve_with(&cfg, &workload, opts, format),
    };
    if let Some(msg) = disagg_fallback {
        report.warnings.push(msg);
    }
    if args.get_bool("json") {
        println!("{}", report::serve_json(&report));
    } else {
        print!("{}", report::serve_table(&report));
    }
    if let Some((path, fleet)) = traced {
        emit_trace(path, &fleet, args.get_bool("json"))?;
    }
    Ok(())
}

fn cmd_shard(args: &Args) -> Result<()> {
    let cfg = model_by_name(args.get_or("model", "gpt-j"))?;
    let format = parse_format(args.get_or("format", "fp8"))?;
    let dies = args.get_u32("dies", 2)?;
    anyhow::ensure!(dies > 0, "--dies must be > 0");
    let batch = args.get_u64("batch", 8)?.max(1);
    let mode = parse_mode(args.get_or("mode", "ar"))?;
    let seq = default_seq(&cfg, args.get_u64("seq", 0)?);
    let objective = match args.get("objective") {
        None => Objective::Throughput,
        Some(s) => Objective::parse(s).ok_or_else(|| {
            anyhow::anyhow!("--objective {s:?}: expected latency or throughput")
        })?,
    };
    let platform = PlatformConfig::with_dies(dies);
    let ranked = best_plans(&cfg, format, &platform, mode, batch, seq, objective);
    anyhow::ensure!(!ranked.is_empty(), "no legal shard plan for this model/die count");
    if args.get_bool("json") {
        println!("{}", report::shard_json(&ranked));
        return Ok(());
    }
    let mode_name = match mode {
        Mode::Nar => "nar",
        Mode::Ar => "ar",
    };
    print!(
        "{}",
        report::shard_table(
            &format!(
                "shard plans — {} {} {} S={seq} b={batch} on {dies} dies, by {}",
                cfg.name,
                mode_name,
                format.name(),
                objective.name()
            ),
            &ranked
        )
    );
    let best = &ranked[0];
    println!(
        "chosen: tp={} pp={} replicas={} ({:.1} tokens/s aggregate, {:.3} Mcycles/token)",
        best.plan.tp,
        best.plan.pp,
        best.plan.replicas,
        best.cost.tokens_per_s,
        best.cost.token_latency_cycles as f64 / 1e6,
    );
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let mut rt = match args.get("artifacts") {
        Some(dir) => Runtime::with_dir(std::path::Path::new(dir))?,
        None => Runtime::new()?,
    };
    println!("PJRT platform: {}", rt.platform_name());
    let names: Vec<String> = rt.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
    for name in names {
        let outs = rt.run_golden(&name, 1e-3)?;
        println!("  {name}: OK ({} outputs)", outs.len());
    }
    println!("all artifacts validated against golden fingerprints");
    Ok(())
}
