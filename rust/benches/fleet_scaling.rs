//! Fleet-scale serving: the event-driven core at 1M requests.
//!
//! Two claims this bench defends:
//!
//! 1. **Event core speedup.** On a sparse-arrival trace (every request
//!    served long before the next lands) the event-heap run loop with
//!    pass-shape memoization beats the legacy per-iteration loop by
//!    >= 10x wall-clock — while producing a bit-identical report
//!    (asserted via `ServeReport::same_outcome`). The pair runs on a
//!    tp=2 shard group, where every legacy pass re-prices rank-local
//!    layers plus collectives; the memo replaces all of it with one
//!    hash-map hit per repeated pass shape.
//!
//! 2. **1M-request fleet trace.** 64 replica engines on OS threads, each
//!    consuming its own seeded lazy diurnal arrival stream
//!    (`Workload::stream_diurnal` — requests are generated as they
//!    arrive, never materialized), merged into one fleet view whose
//!    percentiles come from spilled streaming sketches. Single-digit
//!    CI minutes.
//!
//! Short mode (`BENCH_SMOKE=1`) runs 100k fleet requests instead of 1M;
//! with `BENCH_JSON_DIR` set the results land in `BENCH_fleet.json`
//! (tokens_per_s / ttft_p99_s are trend-tracked).

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{BatcherConfig, ContinuousBatcher, EngineMode, Workload};
use snitch_fm::model::ModelConfig;
use snitch_fm::parallel::{merge_reports, replica_seed, ShardPlan};

const SEED: u64 = 0xF1EE7;
const REPLICAS: usize = 64;

fn main() {
    let cfg = ModelConfig::tiny();
    let fmt = FpFormat::Fp8;

    // ---- Part 1: event vs legacy core on a sparse-arrival trace ----
    let p2 = PlatformConfig::with_dies(2);
    let n_sparse = if common::smoke() { 1_500 } else { 8_000 };
    let sparse = Workload::stream_poisson(SEED, 200.0, n_sparse, 64, 32).materialize();
    let mut ev_opts = BatcherConfig::new(8, 0);
    ev_opts.plan = ShardPlan { tp: 2, pp: 1, replicas: 1 };
    ev_opts.engine = EngineMode::Event;
    let mut it_opts = ev_opts;
    it_opts.engine = EngineMode::Iteration;

    let (t_event, ev) = common::time_median(3, || {
        ContinuousBatcher::new(&cfg, &p2, fmt, ev_opts).run(&sparse)
    });
    let (t_iter, it) = common::time_median(3, || {
        ContinuousBatcher::new(&cfg, &p2, fmt, it_opts).run(&sparse)
    });
    assert!(
        ev.same_outcome(&it),
        "event core must reproduce the legacy loop bit-for-bit"
    );
    assert_eq!(ev.completed, n_sparse);
    let memo_lookups = ev.pass_cache_hits + ev.pass_cache_misses;
    let hit_rate = ev.pass_cache_hits as f64 / memo_lookups.max(1) as f64;
    let speedup = t_iter / t_event;

    common::header(
        "event core",
        "sparse poisson trace, tp=2 shard group: event heap + pass memo vs legacy loop",
    );
    println!(
        "{n_sparse} requests, {} passes, pass-memo hit rate {:.1}%",
        ev.pass_events,
        hit_rate * 100.0
    );
    println!(
        "legacy {:.1} ms, event {:.1} ms -> {speedup:.1}x",
        t_iter * 1e3,
        t_event * 1e3
    );
    common::report_timing("fleet-core-event", t_event);
    common::report_timing("fleet-core-iter", t_iter);
    assert!(
        speedup >= 10.0,
        "event core must be >= 10x the legacy loop on sparse arrivals, got {speedup:.2}x \
         (legacy {:.3}s vs event {:.3}s)",
        t_iter,
        t_event
    );

    // ---- Part 2: 1M-request diurnal trace over 64 threaded replicas ----
    let p1 = PlatformConfig::occamy();
    let per_replica = (if common::smoke() { 100_000 } else { 1_000_000 }) / REPLICAS;
    let total = per_replica * REPLICAS;
    let opts = BatcherConfig::new(8, 0);

    let t0 = std::time::Instant::now();
    let per: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..REPLICAS)
            .map(|r| {
                let (cfg, p1) = (&cfg, &p1);
                s.spawn(move || {
                    let arrivals = Workload::stream_diurnal(
                        replica_seed(SEED, r),
                        300.0,
                        1_200.0,
                        30.0,
                        per_replica,
                        32,
                        16,
                    )
                    .with_priority_classes(2);
                    ContinuousBatcher::new(cfg, p1, fmt, opts).serve_stream(arrivals)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("replica engine panicked"))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let merged = merge_reports(&per, fmt, &p1);

    assert_eq!(merged.requests, total);
    assert_eq!(merged.completed, total, "every request fits and must finish");
    assert_eq!(merged.arrival_events, total as u64);
    assert!(
        !merged.latency_sketch.is_exact(),
        "a {total}-sample latency population must have spilled to histogram mode"
    );
    assert!(merged.tokens_per_s > 0.0);
    assert!(merged.ttft_p99_s > 0.0);
    assert_eq!(merged.per_class.len(), 2);

    common::header(
        "fleet trace",
        "64 threaded replicas, seeded lazy diurnal arrival streams, sketch-merged view",
    );
    println!(
        "{total} requests ({per_replica}/replica), {} gen tokens, {:.1} simulated s",
        merged.gen_tokens, merged.total_seconds
    );
    let fleet_hit_rate = merged.pass_cache_hits as f64
        / (merged.pass_cache_hits + merged.pass_cache_misses).max(1) as f64;
    println!(
        "fleet {:.1} tokens/s  TTFT p50 {:.4} p99 {:.4}  latency p99 {:.4}  \
         pass-memo hit {:.1}%",
        merged.tokens_per_s,
        merged.ttft_p50_s,
        merged.ttft_p99_s,
        merged.latency_p99_s,
        fleet_hit_rate * 100.0
    );
    println!("wall clock {wall_s:.1} s for {} pass events", merged.pass_events);
    common::report_timing("fleet-1m-trace", wall_s);

    common::write_bench_json(
        "fleet",
        &format!(
            "{{\"fleet\":{{\"requests\":{},\"replicas\":{REPLICAS},\"completed\":{},\
             \"tokens_per_s\":{},\"ttft_p99_s\":{},\"latency_p99_s\":{},\
             \"pass_events\":{},\"pass_memo_hit_rate\":{},\"wall_s\":{}}},\
             \"event_core\":{{\"requests\":{n_sparse},\"iter_s\":{t_iter},\
             \"event_s\":{t_event},\"speedup\":{speedup}}}}}",
            merged.requests,
            merged.completed,
            merged.tokens_per_s,
            merged.ttft_p99_s,
            merged.latency_p99_s,
            merged.pass_events,
            fleet_hit_rate,
            wall_s,
        ),
    );
}
