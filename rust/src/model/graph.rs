//! Layer-graph expansion: one transformer block -> the kernel sequence the
//! coordinator schedules (paper Fig. 1/2 block topology, with the fusions
//! of Sec. V-B applied).
//!
//! Every layer carries an explicit batch dimension `b` (concurrent
//! requests whose token rows are stacked) plus the head geometry
//! (`heads`, `p`) the fused concat+linear needs — the schedule no longer
//! has to guess P from K.

use super::{Family, Mode, ModelConfig};

/// Kernel class a layer belongs to (the Fig. 10 breakdown categories).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Plain GEMM (projections, MLP linears).
    Gemm,
    /// FlashAttention-2 fused attention.
    FlashAttention,
    /// Fused Concat+Linear with tree reduction.
    FusedConcatLinear,
    /// LayerNorm.
    Layernorm,
    /// i-GELU (fused with the preceding linear).
    Gelu,
    /// KV-cache precision conversion: dequantize cached KV on read
    /// (kv -> compute) and quantize fresh KV on write (compute -> kv).
    /// Synthesized by the pricing layer when a
    /// [`crate::arch::PrecisionPolicy`] stores KV narrower than it
    /// computes — never part of the block graph expansions, so the
    /// degenerate (uniform) policy's layer lists are untouched.
    KvDequant,
}

impl LayerKind {
    pub const fn name(self) -> &'static str {
        match self {
            LayerKind::Gemm => "gemm",
            LayerKind::FlashAttention => "flashattention",
            LayerKind::FusedConcatLinear => "fused-concat-linear",
            LayerKind::Layernorm => "layernorm",
            LayerKind::Gelu => "gelu",
            LayerKind::KvDequant => "kvdequant",
        }
    }
}

/// One layer instance of the block with concrete dims.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub kind: LayerKind,
    pub label: &'static str,
    /// Batch size: independent requests stacked along the token/row axis.
    /// Weights are shared across the batch, so GEMM-like layers see
    /// `b * m` rows against one weight read, and attention sees
    /// `b * heads` independent head instances.
    pub b: u64,
    /// GEMM: (m, k, n) per request. FA: (heads, sq; skv via `skv`).
    /// LN/GELU: (rows, cols) per request.
    pub m: u64,
    pub k: u64,
    pub n: u64,
    /// FA only: KV length (= S in NAR self-attention; cache length in AR).
    pub skv: u64,
    /// Attention heads of the model (FA instance count per request; the
    /// K-split granularity of the fused concat+linear).
    pub heads: u64,
    /// Per-head projection dim P (exact, from the model config — replaces
    /// the old `cfg_p_guard` divisor guess in the schedule).
    pub p: u64,
    /// GPT causal masking.
    pub causal: bool,
    /// Activations arrive SPM-resident from the previous fused layer.
    pub fused_input: bool,
}

impl Layer {
    /// Token rows this layer processes across the whole batch (GEMM-like
    /// and elementwise layers; FA instead scales head instances).
    pub fn batch_rows(&self) -> u64 {
        self.b * self.m
    }

    /// Independent attention-head instances across the batch (FA layers).
    pub fn batch_heads(&self) -> u64 {
        self.b * self.heads
    }
}

/// Expand one transformer block for a single request (`b = 1`); see
/// [`block_layers_batched`].
pub fn block_layers(cfg: &ModelConfig, mode: Mode, s: u64, kv_len: u64) -> Vec<Layer> {
    block_layers_batched(cfg, mode, 1, s, kv_len)
}

/// Expand one transformer block for `b` concurrent requests, each at
/// sequence length `s` (NAR) or one token against a `kv_len`-entry cache
/// (AR), into its kernel sequence.
///
/// Batching changes *shape*, not topology: the same ten layers come back,
/// each annotated with `b`. The scheduler prices GEMM-like layers with
/// `b*m` rows (one weight stream amortized over the batch — the whole
/// point of batched AR decode) and attention with `b*heads` instances
/// (each request attends to its own KV history).
///
/// In NAR mode `kv_len` is the number of *already-cached* context tokens
/// the `s` new tokens additionally attend to — 0 for a from-scratch
/// prompt (the legacy behavior, bit-identical), positive for a chunked-
/// prefill continuation where earlier chunks populated the cache.
pub fn block_layers_batched(
    cfg: &ModelConfig,
    mode: Mode,
    b: u64,
    s: u64,
    kv_len: u64,
) -> Vec<Layer> {
    let causal = cfg.family == Family::Gpt;
    let (sq, skv) = match mode {
        Mode::Nar => (s, kv_len + s),
        Mode::Ar => (1, kv_len + 1),
    };
    let hp = cfg.hp();
    let layer = |kind, label, m, k, n, skv, causal, fused_input| Layer {
        kind,
        label,
        b,
        m,
        k,
        n,
        skv,
        heads: cfg.heads,
        p: cfg.p,
        causal,
        fused_input,
    };
    vec![
        layer(LayerKind::Layernorm, "ln1", sq, cfg.e, cfg.e, 0, false, false),
        layer(LayerKind::Gemm, "q-proj", sq, cfg.e, hp, 0, false, false),
        layer(LayerKind::Gemm, "k-proj", sq, cfg.e, hp, 0, false, false),
        layer(LayerKind::Gemm, "v-proj", sq, cfg.e, hp, 0, false, false),
        layer(LayerKind::FlashAttention, "attention", cfg.heads, cfg.p, sq, skv, causal, false),
        layer(LayerKind::FusedConcatLinear, "out-proj", sq, hp, cfg.e, 0, false, true),
        layer(LayerKind::Layernorm, "ln2", sq, cfg.e, cfg.e, 0, false, false),
        layer(LayerKind::Gemm, "mlp-up", sq, cfg.e, cfg.ff, 0, false, false),
        layer(LayerKind::Gelu, "gelu", sq, cfg.ff, cfg.ff, 0, false, true),
        layer(LayerKind::Gemm, "mlp-down", sq, cfg.ff, cfg.e, 0, false, true),
    ]
}

/// One transformer block lowered onto a single tensor-parallel rank.
///
/// `layers` is the rank's *local* kernel sequence; `allreduce_elems`
/// lists the element counts of the partial activations the block's
/// induced all-reduces combine across the `tp` ranks (one after the
/// row-split out-projection, one after the row-split mlp-down — the
/// Megatron-style schedule). Empty at `tp = 1`, where `layers` is
/// bit-identical to [`block_layers_batched`].
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedBlock {
    pub layers: Vec<Layer>,
    /// Elements (not bytes) of each all-reduced partial, in block order.
    pub allreduce_elems: Vec<u64>,
}

/// Expand one transformer block as seen by ONE of `tp` tensor-parallel
/// ranks (Megatron-style): the Q/K/V projections and mlp-up are
/// column-split (each rank owns `hp/tp` resp. `ff/tp` output columns),
/// attention keeps `heads/tp` KV heads per rank (each rank's paged-KV
/// pool shrinks accordingly — see `parallel::ShardPlan`), and the
/// out-projection and mlp-down are row-split, leaving each rank with a
/// partial `b*s x E` activation that the induced all-reduce combines.
/// LayerNorms are replicated (every rank needs the full activation).
///
/// `tp` must divide `heads` and `ff` (checked); `tp = 1` returns the
/// unsharded [`block_layers_batched`] expansion bit-identically.
pub fn block_layers_sharded(
    cfg: &ModelConfig,
    mode: Mode,
    b: u64,
    s: u64,
    kv_len: u64,
    tp: u64,
) -> ShardedBlock {
    let tp = tp.max(1);
    if tp == 1 {
        return ShardedBlock {
            layers: block_layers_batched(cfg, mode, b, s, kv_len),
            allreduce_elems: Vec::new(),
        };
    }
    assert!(
        cfg.heads % tp == 0 && cfg.ff % tp == 0,
        "tp={tp} must divide heads={} and ff={}",
        cfg.heads,
        cfg.ff
    );
    let causal = cfg.family == Family::Gpt;
    let (sq, skv) = match mode {
        Mode::Nar => (s, kv_len + s),
        Mode::Ar => (1, kv_len + 1),
    };
    let heads_t = cfg.heads / tp;
    let hp_t = heads_t * cfg.p;
    let ff_t = cfg.ff / tp;
    let layer = |kind, label, m, k, n, skv, causal, fused_input| Layer {
        kind,
        label,
        b,
        m,
        k,
        n,
        skv,
        heads: heads_t,
        p: cfg.p,
        causal,
        fused_input,
    };
    let layers = vec![
        layer(LayerKind::Layernorm, "ln1", sq, cfg.e, cfg.e, 0, false, false),
        layer(LayerKind::Gemm, "q-proj", sq, cfg.e, hp_t, 0, false, false),
        layer(LayerKind::Gemm, "k-proj", sq, cfg.e, hp_t, 0, false, false),
        layer(LayerKind::Gemm, "v-proj", sq, cfg.e, hp_t, 0, false, false),
        layer(LayerKind::FlashAttention, "attention", heads_t, cfg.p, sq, skv, causal, false),
        layer(LayerKind::FusedConcatLinear, "out-proj", sq, hp_t, cfg.e, 0, false, true),
        layer(LayerKind::Layernorm, "ln2", sq, cfg.e, cfg.e, 0, false, false),
        layer(LayerKind::Gemm, "mlp-up", sq, cfg.e, ff_t, 0, false, false),
        layer(LayerKind::Gelu, "gelu", sq, ff_t, ff_t, 0, false, true),
        layer(LayerKind::Gemm, "mlp-down", sq, ff_t, cfg.e, 0, false, true),
    ];
    ShardedBlock { layers, allreduce_elems: vec![b * sq * cfg.e, b * sq * cfg.e] }
}

/// Expand one decode step for `b = kv_lens.len()` concurrent requests
/// with *per-request* KV lengths (each entry is one request's cached
/// tokens, excluding the token being decoded).
///
/// Weight-bound layers (projections, MLP, norms) are shared across the
/// batch exactly as in [`block_layers_batched`], but attention is priced
/// per distinct KV length: the single FlashAttention layer is replaced by
/// one layer per length group, each covering the requests at that length.
/// With a uniform batch this degenerates to the batch-max layer list, so
/// lockstep decode prices identically; ragged batches stop paying the
/// longest resident request's attention price for every short one.
pub fn block_layers_decode(cfg: &ModelConfig, kv_lens: &[u64]) -> Vec<Layer> {
    let b = kv_lens.len() as u64;
    assert!(b > 0, "decode step needs at least one request");
    let mut sorted = kv_lens.to_vec();
    sorted.sort_unstable();
    let mut groups: Vec<(u64, u64)> = Vec::new(); // (kv_len, count)
    for &kv in &sorted {
        match groups.last_mut() {
            Some((g, n)) if *g == kv => *n += 1,
            _ => groups.push((kv, 1)),
        }
    }
    let mut layers = block_layers_batched(cfg, Mode::Ar, b, 1, sorted[0]);
    let at = layers
        .iter()
        .position(|l| l.kind == LayerKind::FlashAttention)
        .expect("block has an attention layer");
    let template = layers[at].clone();
    layers.splice(
        at..=at,
        groups.into_iter().map(|(kv, count)| Layer {
            b: count,
            skv: kv + 1,
            ..template.clone()
        }),
    );
    layers
}

/// Expand one *mixed* scheduler iteration into a single fused kernel
/// sequence (Sarathi-style piggybacking): `prefills` chunk continuations
/// — each `(s, kv_len)` is `s` new prompt tokens attending to `kv_len`
/// already-cached ones — plus one decode token for every entry of
/// `decode_kv` (per-request cached lengths, excluding the token being
/// decoded).
///
/// Weight-bound layers (projections, MLP, norms) stack *every* query
/// token of the iteration — `sum(s_i) + decode_kv.len()` rows against one
/// weight stream — which is exactly why a fused mixed pass undercuts
/// running the prefill passes and the decode pass back to back.
/// Attention stays per-instance: one causal FA layer per prefill chunk
/// (each request attends only to its own history) and one single-query FA
/// group per distinct decode KV length ([`block_layers_decode`]'s
/// grouping). The degenerate forms price bit-identically to the
/// specialized expansions: only-decode matches `block_layers_decode`, and
/// a single prefill with no decode matches `block_layers_batched` at
/// `b = 1`.
pub fn block_layers_mixed(
    cfg: &ModelConfig,
    prefills: &[(u64, u64)],
    decode_kv: &[u64],
) -> Vec<Layer> {
    let q_total: u64 =
        prefills.iter().map(|&(s, _)| s).sum::<u64>() + decode_kv.len() as u64;
    assert!(q_total > 0, "mixed pass needs at least one query token");
    let mut layers = block_layers_batched(cfg, Mode::Nar, 1, q_total, 0);
    splice_mixed_attention(&mut layers, prefills, decode_kv);
    layers
}

/// Replace the single NAR attention layer of a mixed-pass expansion with
/// one causal FA instance per prefill chunk plus one single-query FA
/// group per distinct decode KV length (the [`block_layers_decode`]
/// grouping). The template layer's head geometry is preserved, so the
/// same splice serves the unsharded and TP-rank-local expansions.
fn splice_mixed_attention(layers: &mut Vec<Layer>, prefills: &[(u64, u64)], decode_kv: &[u64]) {
    let at = layers
        .iter()
        .position(|l| l.kind == LayerKind::FlashAttention)
        .expect("block has an attention layer");
    let template = layers[at].clone();
    let mut fa: Vec<Layer> = Vec::new();
    for &(s, kv) in prefills {
        if s == 0 {
            continue;
        }
        fa.push(Layer { n: s, skv: kv + s, ..template.clone() });
    }
    let mut sorted = decode_kv.to_vec();
    sorted.sort_unstable();
    let mut i = 0;
    while i < sorted.len() {
        let kv = sorted[i];
        let mut count = 0u64;
        while i < sorted.len() && sorted[i] == kv {
            count += 1;
            i += 1;
        }
        fa.push(Layer { b: count, n: 1, skv: kv + 1, ..template.clone() });
    }
    layers.splice(at..=at, fa);
}

/// Expand one *mixed* scheduler iteration as seen by ONE of `tp`
/// tensor-parallel ranks: the rank-local column/row-split layer list of
/// [`block_layers_sharded`] with the mixed-pass attention splice of
/// [`block_layers_mixed`] applied on top (per-chunk causal FA instances
/// and per-distinct-KV-length decode groups, each over `heads/tp` local
/// heads). `allreduce_elems` carries the two per-block partial-activation
/// payloads (`q_total x E` each, where `q_total` stacks every query token
/// of the iteration).
///
/// `tp = 1` returns exactly [`block_layers_mixed`]'s list with no
/// collectives, so the serving scheduler's degenerate path is
/// bit-identical to the single-die expansion.
pub fn block_layers_mixed_sharded(
    cfg: &ModelConfig,
    prefills: &[(u64, u64)],
    decode_kv: &[u64],
    tp: u64,
) -> ShardedBlock {
    let tp = tp.max(1);
    if tp == 1 {
        return ShardedBlock {
            layers: block_layers_mixed(cfg, prefills, decode_kv),
            allreduce_elems: Vec::new(),
        };
    }
    let q_total: u64 =
        prefills.iter().map(|&(s, _)| s).sum::<u64>() + decode_kv.len() as u64;
    assert!(q_total > 0, "mixed pass needs at least one query token");
    let mut sb = block_layers_sharded(cfg, Mode::Nar, 1, q_total, 0, tp);
    splice_mixed_attention(&mut sb.layers, prefills, decode_kv);
    sb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nar_block_layers() {
        let cfg = ModelConfig::gpt_j();
        let ls = block_layers(&cfg, Mode::Nar, 1024, 0);
        assert_eq!(ls.len(), 10);
        let att = ls.iter().find(|l| l.kind == LayerKind::FlashAttention).unwrap();
        assert_eq!(att.m, 16);
        assert_eq!(att.n, 1024);
        assert_eq!(att.skv, 1024);
        assert!(att.causal);
        assert_eq!(att.b, 1);
        assert_eq!(att.heads, 16);
        assert_eq!(att.p, 256);
    }

    #[test]
    fn vit_not_causal() {
        let cfg = ModelConfig::vit_b();
        let ls = block_layers(&cfg, Mode::Nar, 197, 0);
        let att = ls.iter().find(|l| l.kind == LayerKind::FlashAttention).unwrap();
        assert!(!att.causal);
    }

    #[test]
    fn ar_block_single_query() {
        let cfg = ModelConfig::gpt_j();
        let ls = block_layers(&cfg, Mode::Ar, 1, 512);
        let att = ls.iter().find(|l| l.kind == LayerKind::FlashAttention).unwrap();
        assert_eq!(att.n, 1); // one query
        assert_eq!(att.skv, 513); // cache + current token
        let q = ls.iter().find(|l| l.label == "q-proj").unwrap();
        assert_eq!(q.m, 1);
    }

    #[test]
    fn fusions_marked() {
        let cfg = ModelConfig::vit_b();
        let ls = block_layers(&cfg, Mode::Nar, 197, 0);
        assert!(ls.iter().find(|l| l.label == "gelu").unwrap().fused_input);
        assert!(ls.iter().find(|l| l.label == "out-proj").unwrap().fused_input);
        assert!(!ls.iter().find(|l| l.label == "q-proj").unwrap().fused_input);
    }

    #[test]
    fn batched_layers_scale_rows_not_topology() {
        let cfg = ModelConfig::gpt_j();
        let one = block_layers_batched(&cfg, Mode::Ar, 1, 1, 1024);
        let eight = block_layers_batched(&cfg, Mode::Ar, 8, 1, 1024);
        assert_eq!(one.len(), eight.len());
        for (a, b) in one.iter().zip(&eight) {
            assert_eq!(a.kind, b.kind);
            assert_eq!((a.m, a.k, a.n, a.skv), (b.m, b.k, b.n, b.skv));
            assert_eq!(b.b, 8);
            assert_eq!(b.batch_rows(), 8 * a.m);
        }
        let att = eight.iter().find(|l| l.kind == LayerKind::FlashAttention).unwrap();
        assert_eq!(att.batch_heads(), 8 * 16);
    }

    #[test]
    fn chunked_prefill_attends_to_cached_context() {
        let cfg = ModelConfig::gpt_j();
        let ls = block_layers_batched(&cfg, Mode::Nar, 1, 128, 512);
        let att = ls.iter().find(|l| l.kind == LayerKind::FlashAttention).unwrap();
        assert_eq!(att.n, 128); // chunk queries
        assert_eq!(att.skv, 640); // cached context + chunk
        // kv_len = 0 is the legacy from-scratch prompt.
        let fresh = block_layers_batched(&cfg, Mode::Nar, 1, 128, 0);
        let att = fresh.iter().find(|l| l.kind == LayerKind::FlashAttention).unwrap();
        assert_eq!(att.skv, 128);
    }

    #[test]
    fn ragged_decode_groups_attention_by_kv_len() {
        let cfg = ModelConfig::gpt_j();
        let ls = block_layers_decode(&cfg, &[512, 64, 512]);
        // 10 layers + 1 extra FA group for the second distinct length.
        assert_eq!(ls.len(), 11);
        let fas: Vec<&Layer> =
            ls.iter().filter(|l| l.kind == LayerKind::FlashAttention).collect();
        assert_eq!(fas.len(), 2);
        assert_eq!((fas[0].b, fas[0].skv), (1, 65));
        assert_eq!((fas[1].b, fas[1].skv), (2, 513));
        // Weight-bound layers stack the whole batch.
        let q = ls.iter().find(|l| l.label == "q-proj").unwrap();
        assert_eq!(q.b, 3);
        assert_eq!(q.batch_rows(), 3);
    }

    #[test]
    fn uniform_decode_equals_batched_layers() {
        let cfg = ModelConfig::gpt_j();
        let ragged = block_layers_decode(&cfg, &[256, 256, 256, 256]);
        let batched = block_layers_batched(&cfg, Mode::Ar, 4, 1, 256);
        assert_eq!(ragged, batched);
    }

    #[test]
    fn mixed_single_prefill_matches_batched_expansion() {
        let cfg = ModelConfig::gpt_j();
        let mixed = block_layers_mixed(&cfg, &[(128, 512)], &[]);
        let batched = block_layers_batched(&cfg, Mode::Nar, 1, 128, 512);
        assert_eq!(mixed, batched);
    }

    #[test]
    fn mixed_pass_stacks_all_query_tokens() {
        let cfg = ModelConfig::gpt_j();
        // Two prefill chunks (64 + 32 tokens) + 3 decode tokens.
        let ls = block_layers_mixed(&cfg, &[(64, 0), (32, 128)], &[512, 64, 512]);
        let q = ls.iter().find(|l| l.label == "q-proj").unwrap();
        assert_eq!(q.batch_rows(), 64 + 32 + 3);
        let fas: Vec<&Layer> =
            ls.iter().filter(|l| l.kind == LayerKind::FlashAttention).collect();
        // 2 prefill instances + 2 distinct decode KV lengths.
        assert_eq!(fas.len(), 4);
        assert_eq!((fas[0].b, fas[0].n, fas[0].skv), (1, 64, 64));
        assert_eq!((fas[1].b, fas[1].n, fas[1].skv), (1, 32, 160));
        assert_eq!((fas[2].b, fas[2].n, fas[2].skv), (1, 1, 65));
        assert_eq!((fas[3].b, fas[3].n, fas[3].skv), (2, 1, 513));
        assert!(fas.iter().all(|l| l.causal));
        // Zero-token prefill entries are dropped.
        let ls = block_layers_mixed(&cfg, &[(0, 64), (16, 0)], &[]);
        assert_eq!(
            ls.iter().filter(|l| l.kind == LayerKind::FlashAttention).count(),
            1
        );
    }

    #[test]
    fn sharded_tp1_is_bit_identical_to_batched() {
        let cfg = ModelConfig::gpt_j();
        for (mode, s, kv) in [(Mode::Nar, 256, 0), (Mode::Nar, 64, 512), (Mode::Ar, 1, 1024)]
        {
            let sb = block_layers_sharded(&cfg, mode, 3, s, kv, 1);
            assert_eq!(sb.layers, block_layers_batched(&cfg, mode, 3, s, kv));
            assert!(sb.allreduce_elems.is_empty());
        }
    }

    #[test]
    fn sharded_block_splits_columns_heads_and_rows() {
        let cfg = ModelConfig::gpt_j(); // 16 heads, p=256, e=4096, ff=16384
        let tp = 4;
        let sb = block_layers_sharded(&cfg, Mode::Nar, 2, 128, 0, tp);
        assert_eq!(sb.layers.len(), 10);
        let by = |l: &str| sb.layers.iter().find(|x| x.label == l).unwrap().clone();
        // Column splits: each rank owns 1/tp of the projection outputs.
        assert_eq!(by("q-proj").n, cfg.hp() / tp);
        assert_eq!(by("mlp-up").n, cfg.ff / tp);
        // KV heads split across ranks.
        let att = by("attention");
        assert_eq!(att.heads, cfg.heads / tp);
        assert_eq!(att.batch_heads(), 2 * cfg.heads / tp);
        // Row splits feed the partial-sum all-reduces.
        assert_eq!(by("out-proj").k, cfg.hp() / tp);
        assert_eq!(by("mlp-down").k, cfg.ff / tp);
        assert_eq!(sb.allreduce_elems, vec![2 * 128 * cfg.e, 2 * 128 * cfg.e]);
        // LayerNorms are replicated at full width.
        assert_eq!(by("ln1").k, cfg.e);
    }

    #[test]
    fn mixed_sharded_tp1_is_bit_identical_to_mixed() {
        let cfg = ModelConfig::gpt_j();
        let prefills = [(64, 0), (32, 128)];
        let decode = [512, 64, 512];
        let sb = block_layers_mixed_sharded(&cfg, &prefills, &decode, 1);
        assert_eq!(sb.layers, block_layers_mixed(&cfg, &prefills, &decode));
        assert!(sb.allreduce_elems.is_empty());
    }

    #[test]
    fn mixed_sharded_single_prefill_matches_sharded_nar_expansion() {
        // A lone prefill chunk on a TP rank is exactly the sharded NAR
        // chunk pass — same layers, same all-reduce payloads — so the
        // serving scheduler's chunk passes price like `plan_cost`'s.
        let cfg = ModelConfig::gpt_j();
        let tp = 4;
        let mixed = block_layers_mixed_sharded(&cfg, &[(128, 512)], &[], tp);
        let nar = block_layers_sharded(&cfg, Mode::Nar, 1, 128, 512, tp);
        assert_eq!(mixed, nar);
    }

    #[test]
    fn mixed_sharded_uniform_decode_matches_sharded_ar_expansion_cost_shape() {
        // A uniform decode-only mixed pass stacks the same rows and head
        // instances as the sharded AR expansion: the (b, m) split differs
        // (b=1,m=4 vs b=4,m=1) but every priced dimension — stacked rows,
        // head instances, KV length, split widths — is identical.
        let cfg = ModelConfig::gpt_j();
        let tp = 2;
        let mixed = block_layers_mixed_sharded(&cfg, &[], &[256; 4], tp);
        let ar = block_layers_sharded(&cfg, Mode::Ar, 4, 1, 256, tp);
        assert_eq!(mixed.allreduce_elems, vec![4 * cfg.e, 4 * cfg.e]);
        assert_eq!(mixed.allreduce_elems, ar.allreduce_elems);
        assert_eq!(mixed.layers.len(), ar.layers.len());
        for (a, b) in mixed.layers.iter().zip(&ar.layers) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.batch_rows(), b.batch_rows(), "{}", a.label);
            assert_eq!((a.k, a.n, a.skv), (b.k, b.n, b.skv), "{}", a.label);
            if a.kind == LayerKind::FlashAttention {
                // The decode FA group is identical in every dimension.
                assert_eq!(a, b);
            }
        }
        // TP splits the mixed pass's projections exactly as the sharded
        // NAR/AR expansions do.
        let q = mixed.layers.iter().find(|l| l.label == "q-proj").unwrap();
        assert_eq!(q.n, cfg.hp() / tp);
        let att = mixed.layers.iter().find(|l| l.kind == LayerKind::FlashAttention).unwrap();
        assert_eq!(att.heads, cfg.heads / tp);
    }

    #[test]
    #[should_panic]
    fn sharded_block_rejects_indivisible_tp() {
        // ViT-B has 12 heads: tp = 8 cannot split them.
        block_layers_sharded(&ModelConfig::vit_b(), Mode::Nar, 1, 197, 0, 8);
    }

    #[test]
    fn exact_head_geometry_on_every_layer() {
        // ViT-B has 12 heads — the old schedule-side divisor guess assumed
        // 16 whenever K % 16 == 0 (768 = 12*64 is divisible by 16, so it
        // guessed wrong); the graph now carries the exact values.
        let cfg = ModelConfig::vit_b();
        for l in block_layers(&cfg, Mode::Nar, 197, 0) {
            assert_eq!(l.heads, 12);
            assert_eq!(l.p, 64);
        }
    }
}
