//! Fig. 7 — impact of SW optimizations on GPT-3XL / GPT-J throughput at
//! S=1024 in NAR and AR modes: baseline FP64 vs the optimized precision
//! ladder. Paper headlines: 16.1x NAR / 35.6x AR total speedup; 260/142
//! tokens/s NAR FP8 and 6.5/2.6 tokens/s AR FP8 for GPT3-XL / GPT-J.

mod common;

use snitch_fm::arch::{Features, FpFormat, PlatformConfig};
use snitch_fm::coordinator::InferenceEngine;
use snitch_fm::model::{Mode, ModelConfig};
use snitch_fm::report;

fn ladder(cfg: &ModelConfig, mode: Mode, seq: u64) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    let mut base = PlatformConfig::occamy();
    base.features = Features::baseline();
    let run = |p: PlatformConfig, fmt| {
        let e = InferenceEngine::new(p);
        match mode {
            Mode::Nar => e.run_nar(cfg, seq, fmt),
            Mode::Ar => e.run_ar_step(cfg, seq, fmt),
        }
        .throughput
    };
    rows.push(("baseline fp64".to_string(), run(base, FpFormat::Fp64)));
    for fmt in FpFormat::LADDER {
        rows.push((
            format!("optimized {}", fmt.name()),
            run(PlatformConfig::occamy(), fmt),
        ));
    }
    rows
}

fn main() {
    common::header("Fig. 7", "GPT SW-optimization ladder, S=1024");
    let paper: [(&str, Mode, f64, f64); 4] = [
        // (model, mode, paper total speedup, paper FP8 throughput tok/s)
        ("gpt3-xl", Mode::Nar, 16.1, 260.0),
        ("gpt-j", Mode::Nar, 16.1, 142.0),
        ("gpt3-xl", Mode::Ar, 35.6, 6.5),
        ("gpt-j", Mode::Ar, 35.6, 2.6),
    ];
    for (name, mode, paper_total, paper_fp8) in paper {
        let cfg = ModelConfig::preset(name).unwrap();
        let label = format!("{name}-{}", if mode == Mode::Nar { "nar" } else { "ar" });
        let (t, rows) = common::time_median(5, || ladder(&cfg, mode, 1024));
        print!(
            "{}",
            report::speedup_ladder(&format!("{label} (ours)"), "tok/s", &rows)
        );
        let total = rows.last().unwrap().1 / rows[0].1;
        println!(
            "  paper: total {paper_total}x, FP8 {paper_fp8} tok/s | ours: total {total:.1}x, FP8 {:.1} tok/s\n",
            rows.last().unwrap().1
        );
        common::report_timing(&label, t);
    }
}
