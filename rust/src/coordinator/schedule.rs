//! Layer -> platform scheduling and pricing (paper Sec. V).
//!
//! Maps each [`Layer`] of a block onto the kernel timing models, honoring
//! the paper's fusion decisions: the out-projection uses the fused
//! concat+linear (tree reduction), GELU is fused with mlp-up, and fused
//! inputs skip their HBM read.
//!
//! Every path is batch-aware: a layer's `b` requests stack along the token
//! rows, so one weight stream from HBM feeds `b*m` rows of work. Batched
//! AR decode therefore turns the pure GEMV (the <10% utilization mode of
//! Table III) into a skinny GEMM whose arithmetic intensity — and FPU
//! utilization — grows with the batch.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};

use crate::arch::{FpFormat, MemLevel, PlatformConfig, PrecisionPolicy, KV_CONVERT_CYCLES_PER_VEC};
use crate::kernels;
use crate::kernels::gemm::OperandHome;
use crate::model::{
    block_layers_batched, block_layers_decode, block_layers_mixed, Layer, LayerKind,
    Mode, ModelConfig,
};
use crate::sim::KernelCost;

use super::breakdown::KindCycles;

/// Row count below which the N-split weight-streaming schedule (each
/// cluster owns output columns, weights read from HBM exactly once) can
/// still beat the M-split blocked schedule, whose per-cluster weight
/// broadcast costs ~C x the HBM reads. At or above `16 * clusters` rows
/// the M-split inner loops are compute-bound enough to hide the broadcast
/// on every geometry in the model zoo, so only the skinny region prices
/// both candidates.
fn skinny_rows_threshold(platform: &PlatformConfig) -> u64 {
    platform.total_clusters() as u64 * 16
}

/// GEMM-layer dispatch on *stacked rows alone* (`b * m`): in the skinny
/// region both candidate schedules are priced and the cheaper one wins, so
/// a batched layer and a single-request layer with the same row count cost
/// the same (the b=2,s=16 vs b=1,s=32 price discontinuity the old
/// `layer.b > 1` guard caused is gone). `gemm_cost` itself falls back to
/// the gemv schedule below `total_clusters` rows, so b = 1 AR decode is
/// bit-identical to the legacy path.
fn gemm_layer_cost(
    rows: u64,
    k: u64,
    n: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
    home: OperandHome,
) -> KernelCost {
    let msplit = kernels::gemm_cost(rows, k, n, fmt, platform, home);
    if rows >= skinny_rows_threshold(platform) {
        return msplit;
    }
    let nsplit = kernels::gemv_cost(rows, k, n, fmt, platform, home);
    if nsplit.cycles < msplit.cycles {
        nsplit
    } else {
        msplit
    }
}

/// Cost of converting `elems` KV elements between the cache and compute
/// precisions (dequantize-on-read kv -> compute, quantize-on-write
/// compute -> kv). Conversions stream through every core's SIMD FPU at
/// the *wider* side's lane width (the expand/round port is the
/// bottleneck, paper Sec. IV-A1), [`KV_CONVERT_CYCLES_PER_VEC`] cycles
/// per vector. No HBM charge: the attention kernels already bill the KV
/// stream at the compute precision, which upper-bounds the narrow-cache
/// traffic — the conversion tax here is deliberately the compute-side
/// cost only.
pub fn kv_convert_cost(
    elems: u64,
    compute: FpFormat,
    kv: FpFormat,
    platform: &PlatformConfig,
) -> KernelCost {
    if elems == 0 || compute == kv {
        return KernelCost::default();
    }
    let lanes = compute.simd_lanes().min(kv.simd_lanes()).max(1);
    let vecs_per_core = elems.div_ceil(lanes).div_ceil(platform.total_cores().max(1));
    let cycles = (vecs_per_core * KV_CONVERT_CYCLES_PER_VEC).max(1);
    KernelCost {
        cycles,
        compute_cycles: cycles,
        flops: elems,
        ..KernelCost::default()
    }
}

/// Cost of one layer on the platform. This is the single dispatch path —
/// the exact head geometry (`heads`, `p`) travels on the [`Layer`], so no
/// caller-side special cases (and no divisor guessing) remain. Uniform
/// precision (`kv == fmt`); the kv-aware entry is
/// [`layer_cost_with_kv`].
pub fn layer_cost(layer: &Layer, fmt: FpFormat, platform: &PlatformConfig) -> KernelCost {
    layer_cost_with_kv(layer, fmt, fmt, platform)
}

/// [`layer_cost`] with the KV-cache precision split from the compute
/// precision: [`LayerKind::KvDequant`] layers price the kv <-> compute
/// conversion of their element count (`(m + n) * 2 * heads * p`: `m`
/// cached tokens dequantized on read, `n` fresh tokens quantized on
/// write), every other kind prices exactly as [`layer_cost`] at `fmt` —
/// the compute format owns the kernels, the KV format owns the cache
/// bytes.
pub fn layer_cost_with_kv(
    layer: &Layer,
    fmt: FpFormat,
    kv: FpFormat,
    platform: &PlatformConfig,
) -> KernelCost {
    let rows = layer.batch_rows();
    match layer.kind {
        LayerKind::KvDequant => kv_convert_cost(
            (layer.m + layer.n) * 2 * layer.heads * layer.p,
            fmt,
            kv,
            platform,
        ),
        LayerKind::Gemm => {
            let home = OperandHome {
                a: if layer.fused_input { MemLevel::Spm } else { MemLevel::Hbm },
                b: MemLevel::Hbm,
                c: MemLevel::Hbm,
            };
            gemm_layer_cost(rows, layer.k, layer.n, fmt, platform, home)
        }
        LayerKind::FlashAttention => kernels::flash_attention_cost(
            // Each request attends to its own KV history: b*H independent
            // head instances spread across the clusters.
            layer.batch_heads(),
            layer.n, // sq
            layer.skv,
            layer.p,
            fmt,
            layer.causal,
            platform,
        ),
        LayerKind::FusedConcatLinear => {
            if platform.features.cluster_to_cluster {
                kernels::fused_concat_linear_cost(
                    rows, layer.heads, layer.p, layer.n, fmt, platform,
                )
            } else {
                kernels::unfused_concat_linear_cost(
                    rows, layer.heads, layer.p, layer.n, fmt, platform,
                )
            }
        }
        LayerKind::Layernorm => kernels::layernorm_cost(rows, layer.k, fmt, platform),
        LayerKind::Gelu => {
            kernels::gelu_cost(rows, layer.k, fmt, layer.fused_input, platform)
        }
    }
}

/// Fingerprint of a platform configuration, used to tag [`LayerCostCache`]
/// instances with the platform *generation* they were priced against. The
/// canonical `Debug` rendering covers every field that can influence a
/// kernel cost (cluster geometry, interconnect, feature flags, clock), so
/// any change to the platform changes the tag.
pub fn platform_fingerprint(platform: &PlatformConfig) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    format!("{platform:?}").hash(&mut h);
    h.finish()
}

/// Interned pricing signature of a layer: exactly the [`Layer`] fields
/// [`layer_cost_with_kv`] reads (the display label is excluded) plus the
/// *precision pair* — the compute format and the KV-cache format. Two
/// layers with equal signatures price identically on a fixed platform,
/// which is what makes the memo below sound; keying the pair (not just
/// the compute format) keeps a [`LayerKind::KvDequant`] layer priced
/// under one policy from aliasing the same shape under another
/// (no-collision asserted in the test suite).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct LayerSig {
    kind: LayerKind,
    b: u64,
    m: u64,
    k: u64,
    n: u64,
    skv: u64,
    heads: u64,
    p: u64,
    causal: bool,
    fused_input: bool,
    fmt: FpFormat,
    kv: FpFormat,
}

impl LayerSig {
    fn of(layer: &Layer, fmt: FpFormat, kv: FpFormat) -> LayerSig {
        LayerSig {
            kind: layer.kind,
            b: layer.b,
            m: layer.m,
            k: layer.k,
            n: layer.n,
            skv: layer.skv,
            heads: layer.heads,
            p: layer.p,
            causal: layer.causal,
            fused_input: layer.fused_input,
            fmt,
            kv,
        }
    }
}

/// Memo over [`layer_cost`]: signature -> [`KernelCost`], tagged with the
/// platform generation it was priced against.
///
/// A serve trace calls `layer_cost` with a small set of distinct
/// signatures millions of times (every decode step re-prices the same
/// projections and MLP layers; attention signatures recur per KV length),
/// but each uncached call re-runs the tile-plan search. The memo makes
/// the pricing hot path a hash lookup — the difference between 50k-request
/// traces being tractable or not — and is *transparent*: the cached cost
/// is bit-identical to the uncached path (property-tested in
/// `proptest_invariants.rs`).
#[derive(Debug)]
pub struct LayerCostCache {
    platform_tag: u64,
    map: HashMap<LayerSig, KernelCost>,
    hits: u64,
    misses: u64,
    generation_flushes: u64,
}

impl LayerCostCache {
    /// An empty cache bound to `platform`'s generation.
    pub fn new(platform: &PlatformConfig) -> LayerCostCache {
        LayerCostCache {
            platform_tag: platform_fingerprint(platform),
            map: HashMap::new(),
            hits: 0,
            misses: 0,
            generation_flushes: 0,
        }
    }

    /// Re-key the memo to `platform`'s generation: when the cache was
    /// priced against a different platform, every memoized price is stale,
    /// so the map is flushed and re-tagged (counted in
    /// [`Self::generation_flushes`]). Unconditional in every build — a
    /// release-build cache reused across platform generations used to
    /// silently serve the old generation's prices (the check was a
    /// `debug_assert`). Called once per model-level pricing, not per
    /// layer, to keep the per-layer hot path a plain hash lookup.
    pub fn ensure_platform(&mut self, platform: &PlatformConfig) {
        let tag = platform_fingerprint(platform);
        if tag != self.platform_tag {
            self.map.clear();
            self.platform_tag = tag;
            self.generation_flushes += 1;
        }
    }

    /// Memoized [`layer_cost`] (uniform precision: `kv == fmt`).
    pub fn layer_cost(
        &mut self,
        layer: &Layer,
        fmt: FpFormat,
        platform: &PlatformConfig,
    ) -> KernelCost {
        self.layer_cost_kv(layer, fmt, fmt, platform)
    }

    /// Memoized [`layer_cost_with_kv`]: the memo key carries the
    /// (compute, kv) precision pair, so mixed-policy prices never alias
    /// uniform ones.
    pub fn layer_cost_kv(
        &mut self,
        layer: &Layer,
        fmt: FpFormat,
        kv: FpFormat,
        platform: &PlatformConfig,
    ) -> KernelCost {
        let sig = LayerSig::of(layer, fmt, kv);
        if let Some(c) = self.map.get(&sig) {
            self.hits += 1;
            return *c;
        }
        let c = layer_cost_with_kv(layer, fmt, kv, platform);
        self.map.insert(sig, c);
        self.misses += 1;
        c
    }

    /// Distinct signatures priced so far.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Credit `n` memo hits without touching the map. Higher-level memos
    /// (the batcher's pass-shape cache) replay the per-layer lookups a
    /// cached pass would have performed — each one a guaranteed hit,
    /// since the pass was priced through this memo the first time — so
    /// hit/miss accounting stays identical whether or not the pass shape
    /// repeated.
    pub fn add_hits(&mut self, n: u64) {
        self.hits += n;
    }

    /// Times the memo was flushed because it was presented a different
    /// platform generation (see [`Self::ensure_platform`]).
    pub fn generation_flushes(&self) -> u64 {
        self.generation_flushes
    }

    /// Fraction of lookups served from the memo.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

/// Per-block and per-model cost summary.
#[derive(Debug, Clone, Default)]
pub struct ModelCost {
    /// Total cycles for one forward pass (NAR) or one token step (AR).
    pub cycles: u64,
    /// Aggregate kernel costs by class.
    pub by_kind: HashMap<LayerKind, KernelCost>,
    /// Aggregate kernel costs by layer label ("q-proj", "mlp-up", ...).
    pub by_label: HashMap<&'static str, KernelCost>,
    /// Total cost.
    pub total: KernelCost,
    /// Blocks priced.
    pub blocks: u64,
    /// Concurrent requests priced together (1 = the legacy single-request
    /// path).
    pub batch: u64,
}

impl ModelCost {
    /// Fraction of cycles spent in `kind`.
    pub fn fraction(&self, kind: LayerKind) -> f64 {
        if self.total.cycles == 0 {
            return 0.0;
        }
        self.by_kind.get(&kind).map(|c| c.cycles as f64).unwrap_or(0.0)
            / self.total.cycles as f64
    }
}

/// Cost of one transformer block for a single request.
pub fn block_cost(
    cfg: &ModelConfig,
    mode: Mode,
    s: u64,
    kv_len: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    block_cost_batched(cfg, mode, 1, s, kv_len, fmt, platform)
}

/// Price a block's layer list into a one-block [`ModelCost`].
fn price_layers(
    layers: &[Layer],
    batch: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    let mut out = ModelCost { blocks: 1, batch, ..Default::default() };
    for layer in layers {
        let c = layer_cost(layer, fmt, platform);
        let slot = out.by_kind.entry(layer.kind).or_default();
        *slot = slot.then(c);
        let slot = out.by_label.entry(layer.label).or_default();
        *slot = slot.then(c);
        out.total = out.total.then(c);
    }
    out.cycles = out.total.cycles;
    out
}

/// Repeat a one-block cost over the model's `blocks` blocks.
fn repeat_blocks(one: &ModelCost, blocks: u64, batch: u64) -> ModelCost {
    let mut out = ModelCost { blocks, batch, ..Default::default() };
    for (k, v) in &one.by_kind {
        out.by_kind.insert(*k, v.repeat(blocks));
    }
    for (k, v) in &one.by_label {
        out.by_label.insert(*k, v.repeat(blocks));
    }
    out.total = one.total.repeat(blocks);
    out.cycles = out.total.cycles;
    out
}

/// Cost of one transformer block for `b` concurrent requests.
pub fn block_cost_batched(
    cfg: &ModelConfig,
    mode: Mode,
    b: u64,
    s: u64,
    kv_len: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    let layers = block_layers_batched(cfg, mode, b.max(1), s, kv_len);
    price_layers(&layers, b.max(1), fmt, platform)
}

/// Cost of a full single-request model pass: `blocks` x block cost. In AR
/// mode, `s` is the current KV length (per-token cost at that point in
/// the sequence).
pub fn model_cost(
    cfg: &ModelConfig,
    mode: Mode,
    s: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    model_cost_batched(cfg, mode, 1, s, fmt, platform)
}

/// Cost of a full model pass over `b` concurrent requests. In AR mode the
/// batch advances one token per request per pass (`b` tokens total
/// against KV length `s`).
pub fn model_cost_batched(
    cfg: &ModelConfig,
    mode: Mode,
    b: u64,
    s: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    let (bs, kv) = match mode {
        Mode::Nar => (s, 0),
        Mode::Ar => (1, s),
    };
    let one = block_cost_batched(cfg, mode, b, bs, kv, fmt, platform);
    repeat_blocks(&one, cfg.blocks, b.max(1))
}

/// Cost of one decode step over requests with *per-request* KV lengths
/// (`kv_lens[i]` = tokens request `i` has cached, excluding the token
/// being decoded). Weight streams are shared across the whole batch;
/// attention is priced per distinct KV length (see
/// [`block_layers_decode`]). A uniform batch prices identically to
/// [`model_cost_batched`] at that length; a ragged batch prices strictly
/// between the all-min and all-max (batch-max) estimates — the batcher no
/// longer bills every request at its longest resident neighbor's length.
pub fn model_cost_decode(
    cfg: &ModelConfig,
    kv_lens: &[u64],
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    if kv_lens.is_empty() {
        return ModelCost::default();
    }
    let layers = block_layers_decode(cfg, kv_lens);
    let one = price_layers(&layers, kv_lens.len() as u64, fmt, platform);
    repeat_blocks(&one, cfg.blocks, kv_lens.len() as u64)
}

/// Cost of one *mixed* iteration over the whole model: `prefills` chunk
/// continuations (each `(s, kv_len)`) plus one decode token per entry of
/// `decode_kv`, fused into a single pass (see
/// [`crate::model::block_layers_mixed`]). The by-kind/by-label breakdown
/// variant of [`model_total_mixed`]; the serving hot path uses the cached
/// total instead.
pub fn model_cost_mixed(
    cfg: &ModelConfig,
    prefills: &[(u64, u64)],
    decode_kv: &[u64],
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ModelCost {
    let batch = prefills.iter().filter(|&&(s, _)| s > 0).count() + decode_kv.len();
    if batch == 0 {
        return ModelCost::default();
    }
    let layers = block_layers_mixed(cfg, prefills, decode_kv);
    let one = price_layers(&layers, batch as u64, fmt, platform);
    repeat_blocks(&one, cfg.blocks, batch as u64)
}

/// Total cost of one mixed iteration over the whole model, priced through
/// the memo. This is the serving scheduler's single pricing entry point:
/// a lone prefill chunk (`prefills = [(s, kv)]`, no decode) prices
/// bit-identically to `block_cost_batched(cfg, Nar, 1, s, kv)` repeated
/// over the blocks, a decode-only call prices bit-identically to
/// [`model_cost_decode`], and a genuinely mixed call prices the fused
/// Sarathi-style pass. Transparent with respect to the uncached
/// [`model_cost_mixed`] (bit-identical totals).
pub fn model_total_mixed(
    costs: &mut LayerCostCache,
    cfg: &ModelConfig,
    prefills: &[(u64, u64)],
    decode_kv: &[u64],
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> KernelCost {
    model_total_mixed_by_kind(costs, cfg, prefills, decode_kv, fmt, platform).0
}

/// [`model_total_mixed`] plus the per-kernel-class cycle split of the same
/// pass. A single walk over the block layers feeds both the total and the
/// [`KindCycles`] accumulator, so the memo hit/miss accounting — and the
/// returned total — are bit-identical to the plain entry point (which now
/// delegates here). The split sums exactly to the total's cycles because
/// [`KernelCost::then`] is additive in cycles and `repeat` scales
/// linearly.
pub fn model_total_mixed_by_kind(
    costs: &mut LayerCostCache,
    cfg: &ModelConfig,
    prefills: &[(u64, u64)],
    decode_kv: &[u64],
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> (KernelCost, KindCycles) {
    model_total_mixed_policy_by_kind(
        costs,
        cfg,
        prefills,
        decode_kv,
        PrecisionPolicy::uniform(fmt),
        platform,
    )
}

/// The per-block KV requantization layer a mixed pass implies under a
/// split-precision policy, or `None` when the pass touches no KV tokens.
/// `m` counts cached tokens dequantized on read (every decode entry's
/// history plus every prefill chunk's cache-so-far), `n` counts fresh
/// tokens quantized on write (one per decode entry plus each chunk's new
/// tokens); [`layer_cost_with_kv`] turns the pair into
/// `(m + n) * 2 * heads * p` converted elements per block.
pub fn kv_requant_layer(
    cfg: &ModelConfig,
    prefills: &[(u64, u64)],
    decode_kv: &[u64],
) -> Option<Layer> {
    let read: u64 = decode_kv.iter().sum::<u64>()
        + prefills.iter().filter(|&&(s, _)| s > 0).map(|&(_, kv)| kv).sum::<u64>();
    let write: u64 = decode_kv.len() as u64
        + prefills.iter().map(|&(s, _)| s).sum::<u64>();
    if read + write == 0 {
        return None;
    }
    Some(Layer {
        kind: LayerKind::KvDequant,
        label: "kv-requant",
        b: 1,
        m: read,
        k: 0,
        n: write,
        skv: 0,
        heads: cfg.heads,
        p: cfg.p,
        causal: false,
        fused_input: false,
    })
}

/// [`model_total_mixed_by_kind`] under a full [`PrecisionPolicy`]: block
/// layers price at `policy.compute`, and when the policy splits the KV
/// format from the compute format
/// ([`PrecisionPolicy::kv_conversion_active`]) one synthetic
/// [`kv_requant_layer`] per block bills the dequant-on-read /
/// quant-on-write conversion under [`LayerKind::KvDequant`]. The
/// degenerate policy ([`PrecisionPolicy::uniform`]) adds no layer and
/// takes the exact legacy walk — bit-identical totals, memo signatures,
/// and hit/miss accounting.
pub fn model_total_mixed_policy_by_kind(
    costs: &mut LayerCostCache,
    cfg: &ModelConfig,
    prefills: &[(u64, u64)],
    decode_kv: &[u64],
    policy: PrecisionPolicy,
    platform: &PlatformConfig,
) -> (KernelCost, KindCycles) {
    if prefills.iter().all(|&(s, _)| s == 0) && decode_kv.is_empty() {
        return (KernelCost::default(), KindCycles::default());
    }
    costs.ensure_platform(platform);
    let mut one = KernelCost::default();
    let mut kinds = KindCycles::default();
    for layer in &block_layers_mixed(cfg, prefills, decode_kv) {
        let c = costs.layer_cost_kv(layer, policy.compute, policy.kv, platform);
        one = one.then(c);
        kinds.add(layer.kind, c.cycles);
    }
    if policy.kv_conversion_active() {
        if let Some(layer) = kv_requant_layer(cfg, prefills, decode_kv) {
            let c = costs.layer_cost_kv(&layer, policy.compute, policy.kv, platform);
            one = one.then(c);
            kinds.add(layer.kind, c.cycles);
        }
    }
    (one.repeat(cfg.blocks), kinds.scaled(cfg.blocks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn gemm_dominates_nar_latency() {
        // Fig. 10: GEMMs are ~66% of GPT-J FP32 NAR latency.
        let cfg = ModelConfig::gpt_j();
        let mc = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp32, &occ());
        let gemm_frac = mc.fraction(LayerKind::Gemm)
            + mc.fraction(LayerKind::FusedConcatLinear);
        assert!(gemm_frac > 0.5, "gemm fraction {gemm_frac}");
        let act_frac = mc.fraction(LayerKind::Layernorm) + mc.fraction(LayerKind::Gelu);
        assert!(act_frac < 0.2, "activations {act_frac}");
    }

    #[test]
    fn ar_gemm_fraction_higher_than_nar() {
        // Fig. 10: AR is even more GEMM-dominated (97% FP32) — the plain
        // GEMV weight streaming eats the token latency.
        let cfg = ModelConfig::gpt_j();
        let nar = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp32, &occ());
        let ar = model_cost(&cfg, Mode::Ar, 1024, FpFormat::Fp32, &occ());
        let f = |mc: &ModelCost| mc.fraction(LayerKind::Gemm);
        assert!(f(&ar) > f(&nar), "ar {} vs nar {}", f(&ar), f(&nar));
        assert!(f(&ar) > 0.85, "ar gemv share {}", f(&ar));
    }

    #[test]
    fn fa_fraction_grows_at_fp8() {
        // Fig. 10: FA-2's relative share grows FP32 -> FP8 (FP32 softmax).
        let cfg = ModelConfig::gpt_j();
        let f32c = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp32, &occ());
        let f8c = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp8, &occ());
        assert!(
            f8c.fraction(LayerKind::FlashAttention)
                > f32c.fraction(LayerKind::FlashAttention),
            "fp8 {} vs fp32 {}",
            f8c.fraction(LayerKind::FlashAttention),
            f32c.fraction(LayerKind::FlashAttention)
        );
    }

    #[test]
    fn model_cost_scales_with_blocks() {
        let mut cfg = ModelConfig::vit_b();
        let one = model_cost(&cfg, Mode::Nar, 197, FpFormat::Fp32, &occ());
        cfg.blocks *= 2;
        let two = model_cost(&cfg, Mode::Nar, 197, FpFormat::Fp32, &occ());
        assert_eq!(two.cycles, 2 * one.cycles);
    }

    #[test]
    fn block_cost_covers_all_kinds() {
        let cfg = ModelConfig::vit_b();
        let bc = block_cost(&cfg, Mode::Nar, 197, 0, FpFormat::Fp32, &occ());
        for kind in [
            LayerKind::Gemm,
            LayerKind::FlashAttention,
            LayerKind::FusedConcatLinear,
            LayerKind::Layernorm,
            LayerKind::Gelu,
        ] {
            assert!(bc.by_kind.contains_key(&kind), "{kind:?} missing");
        }
        let sum: u64 = bc.by_kind.values().map(|c| c.cycles).sum();
        assert_eq!(sum, bc.cycles);
    }

    #[test]
    fn batched_block_flops_scale_linearly() {
        // Useful work is proportional to the batch; NAR attention work too
        // (each request attends within its own sequence).
        let cfg = ModelConfig::gpt_j();
        for mode in [Mode::Nar, Mode::Ar] {
            let (s, kv) = match mode {
                Mode::Nar => (256, 0),
                Mode::Ar => (1, 512),
            };
            let one = block_cost_batched(&cfg, mode, 1, s, kv, FpFormat::Fp32, &occ());
            let four = block_cost_batched(&cfg, mode, 4, s, kv, FpFormat::Fp32, &occ());
            assert_eq!(four.total.flops, 4 * one.total.flops, "{mode:?}");
        }
    }

    #[test]
    fn batched_ar_cheaper_than_serial_decode() {
        // The whole point: one batched step prices far below b serial
        // steps because the weight stream is shared.
        let cfg = ModelConfig::gpt_j();
        let one = model_cost(&cfg, Mode::Ar, 1024, FpFormat::Fp32, &occ());
        let b = 8;
        let batched = model_cost_batched(&cfg, Mode::Ar, b, 1024, FpFormat::Fp32, &occ());
        assert!(
            batched.cycles < b * one.cycles / 2,
            "batched {} vs {}x serial {}",
            batched.cycles,
            b,
            b * one.cycles
        );
    }

    #[test]
    fn gemm_dispatch_depends_on_rows_not_batch() {
        // The fixed discontinuity: b=2,s=16 stacks the same 32 rows as
        // b=1,s=32, so every GEMM-like layer must price identically.
        let cfg = ModelConfig::gpt_j();
        let p = occ();
        let two = block_cost_batched(&cfg, Mode::Nar, 2, 16, 0, FpFormat::Fp32, &p);
        let one = block_cost_batched(&cfg, Mode::Nar, 1, 32, 0, FpFormat::Fp32, &p);
        for label in ["q-proj", "mlp-up", "mlp-down"] {
            assert_eq!(
                two.by_label[label], one.by_label[label],
                "{label}: equal stacked rows must price equally"
            );
        }
    }

    #[test]
    fn skinny_dispatch_never_above_either_schedule() {
        let p = occ();
        for rows in [1u64, 8, 24, 32, 64, 128, 197, 255, 256, 1024] {
            let (k, n) = (4096, 4096);
            let layer = Layer {
                kind: LayerKind::Gemm,
                label: "probe",
                b: 1,
                m: rows,
                k,
                n,
                skv: 0,
                heads: 16,
                p: 256,
                causal: false,
                fused_input: false,
            };
            let got = layer_cost(&layer, FpFormat::Fp32, &p);
            let home = OperandHome::default();
            let ms = kernels::gemm_cost(rows, k, n, FpFormat::Fp32, &p, home);
            let ns = kernels::gemv_cost(rows, k, n, FpFormat::Fp32, &p, home);
            assert!(got.cycles <= ms.cycles, "rows={rows}");
            if rows < p.total_clusters() as u64 * 16 {
                assert!(got.cycles <= ns.cycles, "rows={rows}");
            }
        }
    }

    #[test]
    fn ragged_decode_between_min_and_max_uniform_bounds() {
        let cfg = ModelConfig::gpt_j();
        let p = occ();
        let lens = [64u64, 256, 1024, 1024];
        let ragged = model_cost_decode(&cfg, &lens, FpFormat::Fp32, &p);
        let all_min = model_cost_batched(&cfg, Mode::Ar, 4, 64, FpFormat::Fp32, &p);
        let all_max = model_cost_batched(&cfg, Mode::Ar, 4, 1024, FpFormat::Fp32, &p);
        assert!(ragged.cycles > all_min.cycles);
        assert!(
            ragged.cycles < all_max.cycles,
            "ragged {} must undercut batch-max {}",
            ragged.cycles,
            all_max.cycles
        );
        // Uniform batch degenerates to the batched price exactly.
        let uniform = model_cost_decode(&cfg, &[512; 8], FpFormat::Fp32, &p);
        let batched = model_cost_batched(&cfg, Mode::Ar, 8, 512, FpFormat::Fp32, &p);
        assert_eq!(uniform.total, batched.total);
    }

    #[test]
    fn chunked_prefill_cost_close_to_monolithic() {
        // Chunked prefill redoes no FLOPs (each chunk attends to the cache
        // so far) but pays per-chunk scheduling overheads; the sum of the
        // chunk passes must land within a modest factor of the one-shot
        // prompt cost.
        let cfg = ModelConfig::gpt_j();
        let p = occ();
        let fmt = FpFormat::Fp32;
        let whole = model_cost(&cfg, Mode::Nar, 1024, fmt, &p).cycles;
        let mut chunked = 0u64;
        let chunk = 256;
        for i in 0..(1024 / chunk) {
            chunked += block_cost_batched(&cfg, Mode::Nar, 1, chunk, i * chunk, fmt, &p)
                .total
                .repeat(cfg.blocks)
                .cycles;
        }
        assert!(chunked >= whole, "chunking cannot be free");
        assert!(
            (chunked as f64) < 2.0 * whole as f64,
            "chunk overhead out of band: {chunked} vs {whole}"
        );
    }

    #[test]
    fn mixed_degenerates_to_prefill_and_decode_paths() {
        let cfg = ModelConfig::gpt_j();
        let p = occ();
        let fmt = FpFormat::Fp32;
        // A lone prefill chunk == the chunked-prefill NAR pass.
        let mixed = model_cost_mixed(&cfg, &[(128, 512)], &[], fmt, &p);
        let nar = block_cost_batched(&cfg, Mode::Nar, 1, 128, 512, fmt, &p)
            .total
            .repeat(cfg.blocks);
        assert_eq!(mixed.total, nar);
        // Decode-only == the ragged decode path (same groups, rows stacked
        // the same way).
        let lens = [64u64, 256, 1024, 1024];
        let mixed = model_cost_mixed(&cfg, &[], &lens, fmt, &p);
        let decode = model_cost_decode(&cfg, &lens, fmt, &p);
        assert_eq!(mixed.total, decode.total);
        // Empty forms are zero.
        assert_eq!(model_cost_mixed(&cfg, &[(0, 64)], &[], fmt, &p).cycles, 0);
    }

    #[test]
    fn fused_mixed_pass_undercuts_separate_passes() {
        // The Sarathi claim: one fused prefill+decode pass streams the
        // weights once, so it must beat the chunk pass plus the decode
        // pass run back to back.
        let cfg = ModelConfig::gpt_j();
        let p = occ();
        let fmt = FpFormat::Fp32;
        let lens = [512u64, 700, 900, 1024];
        let fused = model_cost_mixed(&cfg, &[(256, 256)], &lens, fmt, &p);
        let chunk = block_cost_batched(&cfg, Mode::Nar, 1, 256, 256, fmt, &p)
            .total
            .repeat(cfg.blocks);
        let decode = model_cost_decode(&cfg, &lens, fmt, &p);
        assert!(
            fused.cycles < chunk.cycles + decode.total.cycles,
            "fused {} !< separate {}",
            fused.cycles,
            chunk.cycles + decode.total.cycles
        );
        // FLOPs are conserved: fusion removes overhead, not work.
        assert_eq!(fused.total.flops, chunk.flops + decode.total.flops);
    }

    #[test]
    fn layer_cost_cache_is_transparent_and_hits() {
        let cfg = ModelConfig::gpt_j();
        let p = occ();
        let fmt = FpFormat::Fp8;
        let mut cache = LayerCostCache::new(&p);
        let layers = block_layers_batched(&cfg, Mode::Nar, 2, 64, 128);
        for layer in &layers {
            let cached = cache.layer_cost(layer, fmt, &p);
            assert_eq!(cached, layer_cost(layer, fmt, &p), "{}", layer.label);
        }
        let misses = cache.misses();
        assert!(misses >= 1);
        // Second pass over the same layers is all hits, same numbers.
        for layer in &layers {
            assert_eq!(cache.layer_cost(layer, fmt, &p), layer_cost(layer, fmt, &p));
        }
        assert_eq!(cache.misses(), misses, "re-pricing must not miss");
        assert!(cache.hits() >= layers.len() as u64);
        assert!(cache.hit_rate() > 0.0);
        // The memoized model total equals the uncached one bit-for-bit.
        let lens = [64u64, 64, 512];
        let total = model_total_mixed(&mut cache, &cfg, &[(32, 96)], &lens, fmt, &p);
        assert_eq!(total, model_cost_mixed(&cfg, &[(32, 96)], &lens, fmt, &p).total);
    }

    #[test]
    fn by_kind_split_matches_uncached_breakdown() {
        let cfg = ModelConfig::gpt_j();
        let p = occ();
        let fmt = FpFormat::Fp32;
        let mut cache = LayerCostCache::new(&p);
        let prefills = [(128u64, 256u64)];
        let lens = [64u64, 512, 1024];
        let (total, kinds) =
            model_total_mixed_by_kind(&mut cache, &cfg, &prefills, &lens, fmt, &p);
        let uncached = model_cost_mixed(&cfg, &prefills, &lens, fmt, &p);
        assert_eq!(total, uncached.total);
        assert_eq!(kinds.total(), total.cycles, "split must sum to the total");
        for (kind, cycles) in kinds.iter() {
            let want = uncached.by_kind.get(&kind).map(|c| c.cycles).unwrap_or(0);
            assert_eq!(cycles, want, "{kind:?}");
        }
        // Empty pass: both forms zero.
        let (z, zk) = model_total_mixed_by_kind(&mut cache, &cfg, &[(0, 64)], &[], fmt, &p);
        assert_eq!(z.cycles, 0);
        assert!(zk.is_zero());
    }

    #[test]
    fn memo_rekeys_across_platform_generations() {
        // Regression: the generation check was a `debug_assert`, so a
        // release-build cache reused across platforms silently served the
        // old generation's prices (and a debug build panicked instead of
        // recovering). The check is now unconditional and re-keys: the
        // same cache priced against a second platform must flush and
        // return the second platform's exact prices.
        let cfg = ModelConfig::gpt_j();
        let fmt = FpFormat::Fp32;
        let a = occ();
        let mut b = occ();
        b.cluster.compute_efficiency = 0.5;
        let mut cache = LayerCostCache::new(&a);
        let prefills = [(64u64, 0u64)];
        let lens = [128u64, 256];
        let on_a = model_total_mixed(&mut cache, &cfg, &prefills, &lens, fmt, &a);
        assert_eq!(on_a, model_cost_mixed(&cfg, &prefills, &lens, fmt, &a).total);
        assert_eq!(cache.generation_flushes(), 0);
        let on_b = model_total_mixed(&mut cache, &cfg, &prefills, &lens, fmt, &b);
        assert_eq!(
            on_b,
            model_cost_mixed(&cfg, &prefills, &lens, fmt, &b).total,
            "stale generation-A prices must not survive the platform swap"
        );
        assert_ne!(on_a, on_b, "the two generations genuinely price apart");
        assert_eq!(cache.generation_flushes(), 1);
        // Swapping back re-keys again (no resurrection of the old map).
        let back = model_total_mixed(&mut cache, &cfg, &prefills, &lens, fmt, &a);
        assert_eq!(back, on_a);
        assert_eq!(cache.generation_flushes(), 2);
    }

    #[test]
    fn sharded_rank_local_layers_never_collide_with_unsharded_twins() {
        // With sharded pricing sharing the memo, a TP rank's column/row-
        // split layers must never alias their unsharded twins' signatures:
        // prime the cache with the unsharded block, then price the
        // rank-local block through the SAME cache and demand the uncached
        // prices bit-for-bit (an aliased signature would hand back the
        // full-width price).
        use crate::model::block_layers_sharded;
        let cfg = ModelConfig::gpt_j();
        let p = occ();
        let fmt = FpFormat::Fp8;
        for (mode, b, s, kv) in [(Mode::Nar, 2, 128, 0), (Mode::Ar, 4, 1, 512)] {
            let mut cache = LayerCostCache::new(&p);
            for layer in &block_layers_batched(&cfg, mode, b, s, kv) {
                cache.layer_cost(layer, fmt, &p);
            }
            for tp in [2u64, 4] {
                let sb = block_layers_sharded(&cfg, mode, b, s, kv, tp);
                for layer in &sb.layers {
                    let cached = cache.layer_cost(layer, fmt, &p);
                    assert_eq!(
                        cached,
                        layer_cost(layer, fmt, &p),
                        "tp={tp} {} {mode:?}",
                        layer.label
                    );
                }
            }
            // And the split layers genuinely price below full width, so a
            // collision would have been observable above.
            let sb = block_layers_sharded(&cfg, mode, b, s, kv, 4);
            let whole = block_layers_batched(&cfg, mode, b, s, kv);
            for label in ["q-proj", "mlp-up", "mlp-down"] {
                let sharded = sb.layers.iter().find(|l| l.label == label).unwrap();
                let full = whole.iter().find(|l| l.label == label).unwrap();
                assert!(
                    layer_cost(sharded, fmt, &p).cycles < layer_cost(full, fmt, &p).cycles,
                    "{label}"
                );
            }
        }
    }

    #[test]
    fn platform_fingerprint_tracks_generation() {
        let a = platform_fingerprint(&occ());
        assert_eq!(a, platform_fingerprint(&occ()), "deterministic");
        let mut other = occ();
        other.cluster.compute_efficiency = 0.5;
        assert_ne!(a, platform_fingerprint(&other));
        let mut feats = occ();
        feats.features = crate::arch::Features::baseline();
        assert_ne!(a, platform_fingerprint(&feats));
    }

    #[test]
    fn batched_ar_utilization_rises_with_b() {
        let cfg = ModelConfig::gpt_j();
        let p = occ();
        let mut prev = 0.0;
        for b in [1u64, 2, 4, 8, 16, 32] {
            let mc = model_cost_batched(&cfg, Mode::Ar, b, 1024, FpFormat::Fp32, &p);
            let util = metrics::fpu_utilization(&mc.total, FpFormat::Fp32, &p);
            assert!(util > prev, "b={b}: util {util} !> {prev}");
            prev = util;
        }
    }
}
