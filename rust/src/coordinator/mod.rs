//! The inference coordinator (Layer 3).
//!
//! Owns the mapping from model graphs to the platform: prices every layer
//! with the kernel timing models (`schedule`), aggregates per-kernel-class
//! breakdowns (`breakdown`, Fig. 10), runs end-to-end NAR/AR passes and
//! batched multi-request runs (`engine`), schedules multi-user serving
//! traffic with paged-KV continuous batching, chunked prefill and
//! priority-aware admission (`workload`, `kv_paging`, `batcher`), and
//! manages the decode-time KV cache (`kv_cache`) used by the numeric
//! runtime path.
//!
//! The serving surface built on this layer (CLI flags, request
//! lifecycle, JSON schema) is documented in `docs/serving.md`.

#![warn(missing_docs)]

pub mod batcher;
pub mod breakdown;
pub mod engine;
pub mod faults;
pub mod kv_cache;
pub mod kv_paging;
pub mod schedule;
pub mod workload;

pub use batcher::{
    BatcherConfig, ClassStats, ContinuousBatcher, EngineMode, RequestStats, ServeReport,
};
pub use faults::{FaultEvent, FaultKind, FaultPlan, ReplicaFaults, SalvagedRequest};
pub use breakdown::{kind_index, Breakdown, KernelClassShare, KindCycles, KIND_ORDER};
pub use engine::{InferenceEngine, RunReport};
pub use kv_cache::KvCache;
pub use kv_paging::{
    platform_kv_budget_bytes, KvExport, KvGeometry, KvPoolGauges, PagedKvAllocator,
    PageTable, PrefixCache,
};
pub use schedule::{
    block_cost, block_cost_batched, kv_convert_cost, kv_requant_layer, layer_cost,
    layer_cost_with_kv, model_cost, model_cost_batched, model_cost_decode, model_cost_mixed,
    model_total_mixed, model_total_mixed_by_kind, model_total_mixed_policy_by_kind,
    platform_fingerprint, LayerCostCache, ModelCost,
};
pub use workload::{Arrival, ArrivalStream, ClassLadder, Request, SharedPrefix, Workload};
