//! End-to-end inference pricing: full NAR passes, AR generation loops,
//! batched multi-request runs, the continuous-batching serving entry
//! point, and the run reports the CLI/benches print.

use crate::arch::{FpFormat, PlatformConfig};
use crate::coordinator::batcher::{BatcherConfig, ContinuousBatcher, ServeReport};
use crate::coordinator::breakdown::Breakdown;
use crate::coordinator::schedule::{
    model_cost, model_cost_batched, model_total_mixed, LayerCostCache,
};
use crate::coordinator::workload::Workload;
use crate::energy;
use crate::metrics;
use crate::model::{Family, Mode, ModelConfig};
use crate::sim::KernelCost;

/// Everything the paper reports about one run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Model name (e.g. `gpt-j-6b`).
    pub model: String,
    /// Pass mode: `nar` (prefill/encode) or `ar` (decode).
    pub mode: &'static str,
    /// Numeric format the pass was priced at.
    pub format: &'static str,
    /// Sequence length (prompt + generated for generation runs).
    pub seq: u64,
    /// Concurrent requests priced together (1 = single-request).
    pub batch: u64,
    /// Total modeled cycles.
    pub cycles: u64,
    /// Total modeled wall-clock seconds at the platform frequency.
    pub seconds: f64,
    /// End-to-end tokens/s (GPT) or images/s (ViT). For generation runs
    /// this includes prefill time; see `decode_throughput` for the
    /// steady-state decode rate.
    pub throughput: f64,
    /// Unit of `throughput` (`tokens/s` | `images/s`).
    pub throughput_unit: &'static str,
    /// Decode-only tokens/s (generated tokens / decode cycles). Zero for
    /// runs with no decode phase (NAR).
    pub decode_throughput: f64,
    /// Time to first generated token, seconds (prefill + first decode
    /// step). Zero for runs with no decode phase.
    pub ttft_s: f64,
    /// Achieved GFLOP/s over the run.
    pub gflops: f64,
    /// Achieved fraction of the platform's peak FPU throughput.
    pub fpu_utilization: f64,
    /// Modeled average power draw, watts.
    pub power_w: f64,
    /// Energy efficiency (GFLOP/s per watt).
    pub gflops_per_w: f64,
    /// HBM traffic, gigabytes.
    pub hbm_gb: f64,
    /// Chip-to-chip traffic, gigabytes.
    pub c2c_gb: f64,
}

/// Prices full model passes on the simulated platform.
#[derive(Debug, Clone)]
pub struct InferenceEngine {
    /// The platform every pass is priced against.
    pub platform: PlatformConfig,
}

impl InferenceEngine {
    /// An engine for the given platform.
    pub fn new(platform: PlatformConfig) -> InferenceEngine {
        InferenceEngine { platform }
    }

    #[allow(clippy::too_many_arguments)]
    fn report(
        &self,
        cfg: &ModelConfig,
        mode: Mode,
        fmt: FpFormat,
        seq: u64,
        batch: u64,
        cost: KernelCost,
        throughput: f64,
        unit: &'static str,
    ) -> RunReport {
        let power = energy::power_report(&cost, fmt, &self.platform);
        RunReport {
            model: cfg.name.clone(),
            mode: match mode {
                Mode::Nar => "nar",
                Mode::Ar => "ar",
            },
            format: fmt.name(),
            seq,
            batch,
            cycles: cost.cycles,
            seconds: self.platform.cycles_to_seconds(cost.cycles),
            throughput,
            throughput_unit: unit,
            decode_throughput: 0.0,
            ttft_s: 0.0,
            gflops: metrics::achieved_gflops(&cost, &self.platform),
            fpu_utilization: power.fpu_utilization,
            power_w: power.power_w,
            gflops_per_w: power.gflops_per_w,
            hbm_gb: cost.hbm_bytes() as f64 / 1e9,
            c2c_gb: cost.c2c_bytes as f64 / 1e9,
        }
    }

    /// One NAR pass (prompt encoding / ViT classification / training fwd):
    /// produces `seq` tokens (GPT) or one classification (ViT).
    pub fn run_nar(&self, cfg: &ModelConfig, seq: u64, fmt: FpFormat) -> RunReport {
        let mc = model_cost(cfg, Mode::Nar, seq, fmt, &self.platform);
        let (tp, unit) = match cfg.family {
            Family::Gpt => (
                metrics::tokens_per_second_nar(seq, mc.cycles, &self.platform),
                "tokens/s",
            ),
            Family::Vit => {
                (metrics::images_per_second(mc.cycles, &self.platform), "images/s")
            }
        };
        self.report(cfg, Mode::Nar, fmt, seq, 1, mc.total, tp, unit)
    }

    /// Steady-state AR decode at KV length `seq`: cycles for ONE token.
    pub fn run_ar_step(&self, cfg: &ModelConfig, seq: u64, fmt: FpFormat) -> RunReport {
        self.run_ar_step_batched(cfg, 1, seq, fmt)
    }

    /// Steady-state *batched* AR decode: one step advances `b` requests by
    /// one token each against KV length `seq`. At `b = 1` this is exactly
    /// the legacy `run_ar_step`. Throughput is aggregate tokens/s (`b`
    /// tokens per step); FPU utilization rises with `b` as the shared
    /// weight stream amortizes (the Table III <10% ceiling lifts).
    pub fn run_ar_step_batched(
        &self,
        cfg: &ModelConfig,
        b: u64,
        seq: u64,
        fmt: FpFormat,
    ) -> RunReport {
        let b = b.max(1);
        let mc = model_cost_batched(cfg, Mode::Ar, b, seq, fmt, &self.platform);
        let tp =
            b as f64 * metrics::tokens_per_second_ar(mc.cycles, &self.platform);
        let mut r = self.report(cfg, Mode::Ar, fmt, seq, b, mc.total, tp, "tokens/s");
        r.decode_throughput = tp;
        r
    }

    /// Full generation: prefill `prompt_len` tokens (NAR) then decode
    /// `gen_tokens` autoregressively, KV growing each step.
    ///
    /// `throughput` is end-to-end (generated tokens over prefill+decode);
    /// `decode_throughput` divides by decode time only — the number that
    /// was silently conflated before and understated decode speed.
    pub fn run_generate(
        &self,
        cfg: &ModelConfig,
        prompt_len: u64,
        gen_tokens: u64,
        fmt: FpFormat,
    ) -> RunReport {
        self.run_batch(cfg, 1, prompt_len, gen_tokens, fmt)
    }

    /// Batched generation: `b` identical requests prefilled together and
    /// decoded in lockstep (the fixed-batch ancestor of [`Self::serve`]).
    pub fn run_batch(
        &self,
        cfg: &ModelConfig,
        b: u64,
        prompt_len: u64,
        gen_tokens: u64,
        fmt: FpFormat,
    ) -> RunReport {
        let b = b.max(1);
        let prefill =
            model_cost_batched(cfg, Mode::Nar, b, prompt_len, fmt, &self.platform).total;
        let mut total = prefill;
        let mut decode = KernelCost::default();
        let mut first_step_cycles = 0;
        // The decode loop re-prices near-identical steps `gen_tokens`
        // times; the memo turns all but the distinct-KV-length ones into
        // lookups (bit-identical costs — a uniform mixed decode pass is
        // exactly the batched AR block expansion).
        let mut costs = LayerCostCache::new(&self.platform);
        for t in 0..gen_tokens {
            let kv = prompt_len + t;
            let step = model_total_mixed(
                &mut costs,
                cfg,
                &[],
                &vec![kv; b as usize],
                fmt,
                &self.platform,
            );
            if t == 0 {
                first_step_cycles = step.cycles;
            }
            decode = decode.then(step);
        }
        total = total.then(decode);
        let seconds = self.platform.cycles_to_seconds(total.cycles);
        let produced = b * gen_tokens;
        let tp = if total.cycles > 0 {
            produced as f64 / seconds
        } else {
            0.0
        };
        let mut r = self.report(
            cfg,
            Mode::Ar,
            fmt,
            prompt_len + gen_tokens,
            b,
            total,
            tp,
            "tokens/s",
        );
        if decode.cycles > 0 {
            r.decode_throughput =
                produced as f64 / self.platform.cycles_to_seconds(decode.cycles);
            r.ttft_s =
                self.platform.cycles_to_seconds(prefill.cycles + first_step_cycles);
        }
        r
    }

    /// Serve a multi-request workload with continuous batching and the
    /// default scheduler policy (paged KV with prefix caching, monolithic
    /// prefill, single priority class). `max_batch` caps concurrent
    /// resident requests.
    pub fn serve(
        &self,
        cfg: &ModelConfig,
        workload: &Workload,
        max_batch: usize,
        fmt: FpFormat,
    ) -> ServeReport {
        self.serve_with(cfg, workload, BatcherConfig::new(max_batch, 0), fmt)
    }

    /// Serve with explicit scheduler policy (page size, prefill chunking,
    /// full-reservation baseline, aging) and shard plan: with
    /// `opts.plan.tp > 1` / `pp > 1` the engine executes the plan
    /// end-to-end — every pass prices through the TP-rank-local layers
    /// plus the per-iteration all-reduces and pipeline sends, and the
    /// report carries the collective-cycles / d2d-bytes breakdown. A zero
    /// `kv_budget_bytes` in `opts` resolves to the plan's per-replica
    /// budget (for the single plan: HBM capacity minus resident weights
    /// at the serving precision; see [`ContinuousBatcher::new`]).
    pub fn serve_with(
        &self,
        cfg: &ModelConfig,
        workload: &Workload,
        opts: BatcherConfig,
        fmt: FpFormat,
    ) -> ServeReport {
        ContinuousBatcher::new(cfg, &self.platform, fmt, opts).run(workload)
    }

    /// Serve across `replicas` data-parallel replica groups — single-die
    /// engines, or `tp x pp` sharded groups when `opts.plan` says so —
    /// each running the continuous batcher against its own KV budget,
    /// with the given routing policy ([`crate::parallel::router`]).
    /// `replicas = 1` is bit-identical to [`Self::serve_with`].
    pub fn serve_replicated(
        &self,
        cfg: &ModelConfig,
        workload: &Workload,
        opts: BatcherConfig,
        fmt: FpFormat,
        replicas: usize,
        policy: crate::parallel::RoutePolicy,
    ) -> crate::parallel::RouterReport {
        crate::parallel::router::serve_replicated(
            cfg,
            &self.platform,
            fmt,
            opts,
            workload,
            replicas,
            policy,
        )
    }

    /// Serve on a disaggregated fleet: `prefill_replicas` engines run
    /// prompts to prefill-complete, each finished prompt's KV pages
    /// migrate over the die-to-die links (priced by the collectives' p2p
    /// machinery), and `decode_replicas` engines resume the requests
    /// decode-only through the imported-KV admission path. See
    /// [`crate::parallel::router::serve_disaggregated`].
    #[allow(clippy::too_many_arguments)]
    pub fn serve_disaggregated(
        &self,
        cfg: &ModelConfig,
        workload: &Workload,
        opts: BatcherConfig,
        fmt: FpFormat,
        prefill_replicas: usize,
        decode_replicas: usize,
        policy: crate::parallel::RoutePolicy,
    ) -> crate::parallel::DisaggReport {
        crate::parallel::router::serve_disaggregated(
            cfg,
            &self.platform,
            fmt,
            opts,
            workload,
            prefill_replicas,
            decode_replicas,
            policy,
        )
    }

    /// [`Self::serve_replicated`] under an injected fault plan: replica
    /// failures salvage their backlog onto survivors, stalls freeze the
    /// targeted replica's clock, and link faults degrade every group's
    /// collective pricing. A [`crate::coordinator::FaultPlan::off`] plan
    /// is bit-identical to the fault-free entry. See
    /// [`crate::parallel::router::serve_replicated_with_faults`].
    #[allow(clippy::too_many_arguments)]
    pub fn serve_replicated_with_faults(
        &self,
        cfg: &ModelConfig,
        workload: &Workload,
        opts: BatcherConfig,
        fmt: FpFormat,
        replicas: usize,
        policy: crate::parallel::RoutePolicy,
        faults: &crate::coordinator::FaultPlan,
    ) -> crate::parallel::RouterReport {
        crate::parallel::router::serve_replicated_with_faults(
            cfg,
            &self.platform,
            fmt,
            opts,
            workload,
            replicas,
            policy,
            faults,
        )
    }

    /// [`Self::serve_disaggregated`] under an injected fault plan:
    /// replica faults land on the decode fleet, link faults degrade the
    /// KV-migration path, and corrupted migrations retry with capped
    /// exponential backoff before falling back to decode-side prefill
    /// recompute. See
    /// [`crate::parallel::router::serve_disaggregated_with_faults`].
    #[allow(clippy::too_many_arguments)]
    pub fn serve_disaggregated_with_faults(
        &self,
        cfg: &ModelConfig,
        workload: &Workload,
        opts: BatcherConfig,
        fmt: FpFormat,
        prefill_replicas: usize,
        decode_replicas: usize,
        policy: crate::parallel::RoutePolicy,
        faults: &crate::coordinator::FaultPlan,
    ) -> crate::parallel::DisaggReport {
        crate::parallel::router::serve_disaggregated_with_faults(
            cfg,
            &self.platform,
            fmt,
            opts,
            workload,
            prefill_replicas,
            decode_replicas,
            policy,
            faults,
        )
    }

    /// HBM bytes left for KV caches once the model weights are resident
    /// at serving precision. Zero when the weights alone exceed capacity
    /// (the serve path then rejects everything rather than pretending).
    pub fn kv_budget_bytes(&self, cfg: &ModelConfig, fmt: FpFormat) -> u64 {
        crate::coordinator::kv_paging::platform_kv_budget_bytes(cfg, fmt, &self.platform)
    }

    /// Fig. 10 latency breakdown for a pass.
    pub fn breakdown(&self, cfg: &ModelConfig, mode: Mode, seq: u64, fmt: FpFormat) -> Breakdown {
        let mc = model_cost(cfg, mode, seq, fmt, &self.platform);
        Breakdown::from_cost(&mc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> InferenceEngine {
        InferenceEngine::new(PlatformConfig::occamy())
    }

    #[test]
    fn nar_utilization_in_paper_band() {
        // Table III: GPT-J S=1024 NAR utilizations 65-80%.
        let e = engine();
        let cfg = ModelConfig::gpt_j();
        for (fmt, lo, hi) in [
            (FpFormat::Fp64, 0.55, 0.95),
            (FpFormat::Fp32, 0.55, 0.95),
            (FpFormat::Fp16, 0.45, 0.90),
            (FpFormat::Fp8, 0.40, 0.85),
        ] {
            let r = e.run_nar(&cfg, 1024, fmt);
            assert!(
                (lo..=hi).contains(&r.fpu_utilization),
                "{fmt}: util {}",
                r.fpu_utilization
            );
        }
    }

    #[test]
    fn ar_utilization_below_15pct() {
        // Table III: AR utilization < 10% at every precision.
        let e = engine();
        let cfg = ModelConfig::gpt_j();
        for fmt in FpFormat::LADDER {
            let r = e.run_ar_step(&cfg, 1024, fmt);
            assert!(r.fpu_utilization < 0.15, "{fmt}: util {}", r.fpu_utilization);
            assert!(r.fpu_utilization > 0.005, "{fmt}: util {}", r.fpu_utilization);
        }
    }

    #[test]
    fn nar_beats_ar_in_utilization() {
        let e = engine();
        let cfg = ModelConfig::gpt3_xl();
        let nar = e.run_nar(&cfg, 1024, FpFormat::Fp32);
        let ar = e.run_ar_step(&cfg, 1024, FpFormat::Fp32);
        assert!(nar.fpu_utilization > 5.0 * ar.fpu_utilization);
    }

    #[test]
    fn vit_reports_images_per_second() {
        let e = engine();
        let r = e.run_nar(&ModelConfig::vit_b(), 197, FpFormat::Fp8);
        assert_eq!(r.throughput_unit, "images/s");
        // Paper: 26 images/s for ViT-B FP8 — same order of magnitude.
        assert!(r.throughput > 5.0 && r.throughput < 120.0, "{}", r.throughput);
    }

    #[test]
    fn generate_slower_than_single_step_estimate() {
        let e = engine();
        let cfg = ModelConfig::tiny();
        let gen = e.run_generate(&cfg, 16, 8, FpFormat::Fp32);
        let step = e.run_ar_step(&cfg, 16, FpFormat::Fp32);
        assert!(gen.cycles > step.cycles, "prefill + 8 steps > 1 step");
    }

    #[test]
    fn generate_splits_decode_from_e2e_throughput() {
        let e = engine();
        let cfg = ModelConfig::tiny();
        let r = e.run_generate(&cfg, 64, 8, FpFormat::Fp32);
        // Prefill time is in the e2e denominator only, so decode-only
        // throughput is strictly higher; TTFT covers prefill+first step.
        assert!(r.decode_throughput > r.throughput, "{r:?}");
        assert!(r.ttft_s > 0.0 && r.ttft_s < r.seconds, "{r:?}");
        let step = e.run_ar_step(&cfg, 64, FpFormat::Fp32);
        // Steady-state decode rate is near the single-step estimate.
        assert!(
            r.decode_throughput < 1.2 * step.throughput,
            "decode {} vs step {}",
            r.decode_throughput,
            step.throughput
        );
    }

    #[test]
    fn batched_step_matches_legacy_at_b1() {
        let e = engine();
        let cfg = ModelConfig::gpt_j();
        for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
            let old = e.run_ar_step(&cfg, 1024, fmt);
            let new = e.run_ar_step_batched(&cfg, 1, 1024, fmt);
            assert_eq!(old.cycles, new.cycles, "{fmt}");
            assert_eq!(old.throughput, new.throughput, "{fmt}");
            assert_eq!(old.fpu_utilization, new.fpu_utilization, "{fmt}");
        }
    }

    #[test]
    fn batched_decode_raises_utilization_and_throughput() {
        let e = engine();
        let cfg = ModelConfig::gpt_j();
        let one = e.run_ar_step_batched(&cfg, 1, 1024, FpFormat::Fp32);
        let sixteen = e.run_ar_step_batched(&cfg, 16, 1024, FpFormat::Fp32);
        assert!(sixteen.fpu_utilization > 4.0 * one.fpu_utilization);
        assert!(sixteen.throughput > 4.0 * one.throughput);
        assert!(sixteen.batch == 16 && one.batch == 1);
    }

    #[test]
    fn memoized_generation_matches_uncached_pricing() {
        // The run_batch decode loop now prices through the layer memo;
        // the trace cost must stay bit-identical to the uncached per-step
        // composition it replaced.
        use crate::coordinator::schedule::block_cost_batched;
        let e = engine();
        let cfg = ModelConfig::tiny();
        let fmt = FpFormat::Fp32;
        let r = e.run_batch(&cfg, 3, 32, 6, fmt);
        let mut total =
            model_cost_batched(&cfg, Mode::Nar, 3, 32, fmt, &e.platform).total;
        for t in 0..6u64 {
            let step =
                block_cost_batched(&cfg, Mode::Ar, 3, 1, 32 + t, fmt, &e.platform)
                    .total
                    .repeat(cfg.blocks);
            total = total.then(step);
        }
        assert_eq!(r.cycles, total.cycles);
    }

    #[test]
    fn serve_smoke_tiny() {
        let e = engine();
        let cfg = ModelConfig::tiny();
        let w = Workload::uniform(8, 16, 8);
        let r = e.serve(&cfg, &w, 4, FpFormat::Fp32);
        assert_eq!(r.completed, 8);
        assert!(r.tokens_per_s > 0.0);
        assert!(r.peak_kv_bytes <= e.kv_budget_bytes(&cfg, FpFormat::Fp32));
    }

    #[test]
    fn kv_budget_accounts_for_weights() {
        let e = engine();
        let cfg = ModelConfig::gpt_j();
        let cap = e.platform.interconnect.hbm_capacity_bytes;
        assert_eq!(
            e.kv_budget_bytes(&cfg, FpFormat::Fp8),
            cap - cfg.weight_bytes(FpFormat::Fp8)
        );
        // FP8 weights leave more room than FP32 weights.
        assert!(
            e.kv_budget_bytes(&cfg, FpFormat::Fp8)
                > e.kv_budget_bytes(&cfg, FpFormat::Fp32)
        );
    }

    #[test]
    fn power_between_idle_and_max() {
        let e = engine();
        let r = e.run_nar(&ModelConfig::gpt_j(), 1024, FpFormat::Fp32);
        assert!(r.power_w > energy::P_STATIC_W);
        assert!(r.power_w < energy::P_STATIC_W + energy::P_ACTIVE_W);
    }
}
