//! Shared helpers for the integration tests (each test binary compiles
//! its own copy via `mod common;`).

/// Deterministic 64-bit LCG over a seed; yields values in `[lo, hi]`.
/// The single definition the test binaries share (the crate-internal
/// generator lives in `coordinator::workload`).
pub struct Rng(pub u64);

#[allow(dead_code)]
impl Rng {
    pub fn next(&mut self, lo: u64, hi: u64) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        lo + (self.0 >> 33) % (hi - lo + 1)
    }

    pub fn pick<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[self.next(0, xs.len() as u64 - 1) as usize]
    }
}
