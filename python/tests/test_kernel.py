"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compute layer: if these pass,
the HLO artifacts the Rust coordinator executes are numerically equivalent
to the textbook math, across tilings and dtypes (FP32/BF16/FP16/FP8 — the
paper's precision ladder, minus FP64 which jax CPU covers via float64).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import flash_attention as fa
from compile.kernels import gelu as gelu_k
from compile.kernels import gemm as gemm_k
from compile.kernels import layernorm as ln_k
from compile.kernels import ref
from compile.kernels import softmax as sm_k
from compile.kernels.util import pick_block

RNG = np.random.default_rng(1234)

# dtype -> (rtol, atol): tolerance widens with shorter mantissas.
TOLS = {
    jnp.float32: (1e-5, 1e-5),
    jnp.bfloat16: (3e-2, 3e-2),
    jnp.float16: (5e-3, 5e-3),
    jnp.float8_e4m3fn: (2.5e-1, 2.5e-1),  # paper's FP8ALT (E4M3)
    jnp.float8_e5m2: (5e-1, 5e-1),        # paper's FP8 (E5M2)
}
DTYPES = list(TOLS)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(dtype)


def check(got, want, dtype):
    rtol, atol = TOLS[dtype]
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(want, dtype=np.float32),
        rtol=rtol, atol=atol)


# ---------------------------------------------------------------- pick_block
@pytest.mark.parametrize("dim,want,expect", [
    (64, 64, 64), (64, 48, 32), (197, 64, 197), (1, 64, 1),
    (48, 64, 48), (2048, 64, 64), (100, 64, 50), (30, 8, 30),
])
def test_pick_block(dim, want, expect):
    b = pick_block(dim, want)
    assert b == expect
    assert dim % b == 0


# --------------------------------------------------------------------- GEMM
@pytest.mark.parametrize("dtype", DTYPES)
def test_gemm_dtypes(dtype):
    a, b = rand((32, 48), dtype), rand((48, 24), dtype)
    check(gemm_k.gemm(a, b, bm=16, bn=8, bk=16), ref.gemm(a, b), dtype)


@pytest.mark.parametrize("m,n,k", [(8, 8, 8), (64, 32, 128), (197, 64, 768),
                                   (1, 64, 64), (33, 17, 9)])
def test_gemm_shapes(m, n, k):
    a, b = rand((m, k)), rand((k, n))
    # Tolerance scales with the accumulation length: tiled K-order differs
    # from jnp's single-pass matmul by O(sqrt(K)) ulps.
    atol = 1e-5 * max(1.0, k**0.5)
    np.testing.assert_allclose(
        np.asarray(gemm_k.gemm(a, b)), np.asarray(ref.gemm(a, b)),
        rtol=1e-4, atol=atol)


def test_gemm_alpha():
    a, b = rand((16, 16)), rand((16, 16))
    # alpha is the paper's 1/sqrt(P) attention scaling folded into the GEMM
    check(gemm_k.gemm(a, b, alpha=0.125), ref.gemm(a, b, alpha=0.125),
          jnp.float32)


def test_gemm_identity():
    a = rand((24, 24))
    check(gemm_k.gemm(a, np.eye(24, dtype=np.float32)), a, jnp.float32)


def test_gemm_tile_invariance():
    """Different SPM tilings must agree bit-for-bit in structure (allclose)."""
    a, b = rand((64, 64)), rand((64, 64))
    base = gemm_k.gemm(a, b, bm=64, bn=64, bk=64)
    for bm, bn, bk in [(8, 8, 8), (16, 32, 64), (64, 8, 16), (32, 32, 32)]:
        check(gemm_k.gemm(a, b, bm=bm, bn=bn, bk=bk), base, jnp.float32)


# --------------------------------------------------------- FlashAttention-2
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("causal", [False, True])
def test_fa_dtypes(dtype, causal):
    q, k, v = (rand((4, 32, 16), dtype, 0.5) for _ in range(3))
    got = fa.flash_attention(q, k, v, causal=causal, bq=8, bkv=8)
    want = np.stack([ref.attention(q[h], k[h], v[h], causal=causal)
                     for h in range(4)])
    check(got, want, dtype)


def test_fa_fp8():
    dtype = jnp.float8_e4m3fn
    q, k, v = (rand((2, 16, 8), dtype, 0.5) for _ in range(3))
    got = fa.flash_attention(q, k, v, bq=8, bkv=8)
    want = np.stack([ref.attention(q[h], k[h], v[h]) for h in range(2)])
    check(got, want, dtype)


@pytest.mark.parametrize("sq,skv", [(32, 32), (1, 32), (8, 64), (197, 197),
                                    (16, 16)])
def test_fa_shapes(sq, skv):
    q = rand((2, sq, 32))
    k, v = rand((2, skv, 32)), rand((2, skv, 32))
    got = fa.flash_attention(q, k, v, causal=True, bq=8, bkv=8)
    want = np.stack([ref.attention(q[h], k[h], v[h], causal=True)
                     for h in range(2)])
    check(got, want, jnp.float32)


def test_fa_tile_invariance():
    q, k, v = (rand((2, 64, 16)) for _ in range(3))
    base = fa.flash_attention(q, k, v, causal=True, bq=64, bkv=64)
    for bq, bkv in [(8, 8), (16, 64), (64, 8), (32, 16)]:
        check(fa.flash_attention(q, k, v, causal=True, bq=bq, bkv=bkv),
              base, jnp.float32)


def test_fa_matches_unfused_softmax_path():
    """FA-2 must equal the baseline (unfused GEMM+softmax+GEMM) pipeline."""
    q, k, v = (rand((1, 32, 16)) for _ in range(3))
    s = gemm_k.gemm(q[0], np.asarray(k[0]).T, alpha=1.0 / 4.0)
    a = sm_k.softmax(s)
    want = gemm_k.gemm(a, v[0])
    got = fa.flash_attention(q, k, v)[0]
    check(got, want, jnp.float32)


def test_fa_single_query_decode():
    """AR decode shape: one query vs a long KV history (paper's GEMV path)."""
    q = rand((4, 1, 16))
    k, v = rand((4, 128, 16)), rand((4, 128, 16))
    got = fa.flash_attention(q, k, v, causal=True, bq=1, bkv=16)
    want = np.stack([ref.attention(q[h], k[h], v[h], causal=True)
                     for h in range(4)])
    check(got, want, jnp.float32)


# ---------------------------------------------------------------- LayerNorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_layernorm_dtypes(dtype):
    x = rand((32, 48), dtype)
    g, b = rand(48, jnp.float32, 0.2) + 1.0, rand(48, jnp.float32, 0.2)
    check(ln_k.layernorm(x, g.astype(dtype), b.astype(dtype), br=8),
          ref.layernorm(x, g, b), dtype)


def test_layernorm_rows_independent():
    """Permuting rows must permute outputs (no cross-row leakage)."""
    x = rand((16, 32))
    g, b = np.ones(32, np.float32), np.zeros(32, np.float32)
    perm = RNG.permutation(16)
    got = np.asarray(ln_k.layernorm(x[perm], g, b, br=4))
    want = np.asarray(ln_k.layernorm(x, g, b, br=4))[perm]
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_layernorm_statistics():
    """Unit gamma/zero beta output has ~zero mean, ~unit variance per row."""
    x = rand((8, 256), scale=3.0)
    y = np.asarray(ln_k.layernorm(x, np.ones(256, np.float32),
                                  np.zeros(256, np.float32)))
    np.testing.assert_allclose(y.mean(axis=1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.var(axis=1), 1.0, atol=1e-3)


# -------------------------------------------------------------------- GELU
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_gelu_dtypes(dtype):
    x = rand((32, 16), dtype)
    check(gelu_k.i_gelu(x, br=8), ref.i_gelu(x), dtype)


def test_gelu_vs_exact_gelu():
    """i-GELU is an approximation: must stay close to exact GELU."""
    x = np.linspace(-4, 4, 101, dtype=np.float32).reshape(1, -1)
    got = np.asarray(gelu_k.i_gelu(x)).ravel()
    exact = np.asarray(jax.nn.gelu(x, approximate=False)).ravel()
    # Kim et al. report max error ~1e-2 over the useful range.
    assert np.max(np.abs(got - exact)) < 2e-2


def test_gelu_limits():
    """GELU(x) -> x for large x, -> 0 for very negative x."""
    x = np.array([[10.0, -10.0, 0.0]], dtype=np.float32)
    y = np.asarray(gelu_k.i_gelu(x)).ravel()
    np.testing.assert_allclose(y[0], 10.0, atol=1e-3)
    np.testing.assert_allclose(y[1], 0.0, atol=1e-3)
    np.testing.assert_allclose(y[2], 0.0, atol=1e-6)


# ------------------------------------------------------------------ Softmax
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_softmax_dtypes(dtype):
    x = rand((32, 48), dtype)
    check(sm_k.softmax(x, br=8), ref.softmax(x), dtype)


def test_softmax_rows_sum_to_one():
    x = rand((16, 64), scale=5.0)
    y = np.asarray(sm_k.softmax(x))
    np.testing.assert_allclose(y.sum(axis=-1), 1.0, rtol=1e-5)
    assert (y >= 0).all()


def test_softmax_stability_large_logits():
    """The fp32 max-subtraction must survive huge logits without NaN/Inf."""
    x = np.array([[1e4, 1e4 - 1.0, 0.0]], dtype=np.float32)
    y = np.asarray(sm_k.softmax(x))
    assert np.isfinite(y).all()
    np.testing.assert_allclose(y.sum(), 1.0, rtol=1e-5)
