//! State-of-the-art comparison data and calculators (paper Tables I & IV).
//!
//! The SoA numbers are constants transcribed from the paper (which in turn
//! sources Emani et al. for the GPT2-XL forward pass and MLPerf for the
//! H100 ViT-L benchmark); our side of each comparison is produced by the
//! simulator at bench time.

/// One accelerator platform's published numbers (Table IV, FP16 NAR).
#[derive(Debug, Clone)]
pub struct SoaPlatform {
    pub name: &'static str,
    /// Compute units (CUDA cores + tensor cores, PCUs, TPCs+MMEs, ...).
    pub compute_units: u64,
    /// Throughput on the GPT2-XL training-forward (== NAR) pass, TFLOPS.
    pub tflops: f64,
    /// TFLOPS per compute unit.
    pub tflops_per_cu: f64,
    /// FPU/compute utilization (achieved / peak), percent.
    pub fpu_utilization_pct: f64,
}

/// Table IV rows (SoA columns): A100, MI250, SN30, Gaudi2.
pub fn table4_soa() -> Vec<SoaPlatform> {
    vec![
        SoaPlatform {
            name: "A100",
            compute_units: 6912 + 432,
            tflops: 5.63,
            tflops_per_cu: 0.0008,
            fpu_utilization_pct: 14.4,
        },
        SoaPlatform {
            name: "MI250",
            compute_units: 13312 + 208,
            tflops: 3.75,
            tflops_per_cu: 0.0003,
            fpu_utilization_pct: 7.8,
        },
        SoaPlatform {
            name: "SN30",
            compute_units: 1280,
            tflops: 13.8,
            tflops_per_cu: 0.0107,
            fpu_utilization_pct: 16.0,
        },
        SoaPlatform {
            name: "Gaudi2",
            compute_units: 24 + 2,
            tflops: 11.3,
            tflops_per_cu: 0.4327,
            fpu_utilization_pct: 34.6,
        },
    ]
}

/// H100 MLPerf ViT-L FP8 reference (Sec. VII-E).
#[derive(Debug, Clone, Copy)]
pub struct H100VitRef {
    pub samples_per_s: f64,
    pub power_w: f64,
    pub compute_units: u64,
    pub samples_per_s_per_cu: f64,
    pub samples_per_s_per_w: f64,
}

pub fn h100_vit_l_fp8() -> H100VitRef {
    H100VitRef {
        samples_per_s: 2683.0,
        power_w: 670.0,
        compute_units: 17424,
        samples_per_s_per_cu: 0.15,
        samples_per_s_per_w: 4.0,
    }
}

/// Academic accelerator references (Sec. VII-E).
#[derive(Debug, Clone, Copy)]
pub struct AcademicRef {
    pub name: &'static str,
    /// AccelTran: W per PE. Tambe et al.: BERT-base latency @1 GHz, ms.
    pub watts_per_pe: Option<f64>,
    pub bert_base_latency_ms: Option<f64>,
}

pub fn acceltran() -> AcademicRef {
    AcademicRef { name: "AccelTran", watts_per_pe: Some(14.03 / 64.0), bert_base_latency_ms: None }
}

pub fn tambe() -> AcademicRef {
    AcademicRef { name: "Tambe et al.", watts_per_pe: None, bert_base_latency_ms: Some(489.0) }
}

/// Our row of Table IV, computed from a simulated run.
#[derive(Debug, Clone)]
pub struct OursRow {
    pub compute_units: u64,
    pub tflops: f64,
    pub tflops_per_cu: f64,
    pub fpu_utilization_pct: f64,
}

impl OursRow {
    pub fn from_run(gflops: f64, utilization: f64, compute_units: u64) -> OursRow {
        OursRow {
            compute_units,
            tflops: gflops / 1e3,
            tflops_per_cu: gflops / 1e3 / compute_units as f64,
            fpu_utilization_pct: utilization * 100.0,
        }
    }

    /// Utilization advantage over the best SoA platform (paper: 2.04x
    /// vs Gaudi2).
    pub fn utilization_advantage(&self) -> f64 {
        let best = table4_soa()
            .iter()
            .map(|s| s.fpu_utilization_pct)
            .fold(f64::MIN, f64::max);
        self.fpu_utilization_pct / best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_constants_sane() {
        let rows = table4_soa();
        assert_eq!(rows.len(), 4);
        let gaudi = rows.iter().find(|r| r.name == "Gaudi2").unwrap();
        assert!(gaudi.fpu_utilization_pct > 34.0);
        for r in &rows {
            let derived = r.tflops / r.compute_units as f64;
            // tflops_per_cu column is rounded in the paper; allow slack.
            assert!(
                (derived - r.tflops_per_cu).abs() / r.tflops_per_cu < 0.5,
                "{}: {derived} vs {}",
                r.name,
                r.tflops_per_cu
            );
        }
    }

    #[test]
    fn ours_advantage_matches_paper_with_paper_numbers() {
        // Feeding the paper's own numbers (0.72 TFLOPS, 70.6% util, 128 CUs)
        // must reproduce the 2.04x Gaudi2 advantage.
        let ours = OursRow::from_run(720.0, 0.706, 128);
        let adv = ours.utilization_advantage();
        assert!((adv - 2.04).abs() < 0.03, "advantage {adv}");
        assert!((ours.tflops_per_cu - 0.0056).abs() < 0.0003);
    }

    #[test]
    fn h100_reference() {
        let h = h100_vit_l_fp8();
        assert!((h.samples_per_s / h.compute_units as f64 - 0.15).abs() < 0.01);
        assert!((h.samples_per_s / h.power_w - 4.0).abs() < 0.05);
    }

    #[test]
    fn academic_references() {
        assert!((acceltran().watts_per_pe.unwrap() - 0.22).abs() < 0.01);
        assert_eq!(tambe().bert_base_latency_ms, Some(489.0));
    }
}
